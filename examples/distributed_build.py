"""Fault-tolerant distributed build: failures, stragglers, checkpoint resume.

Demonstrates the cluster runtime features the 10-billion-scale deployment
relies on (DESIGN.md §4):

  1. build on a virtual cluster that kills workers mid-task and injects 5×
     stragglers — retries + speculative execution absorb both;
  2. kill the build halfway (simulated crash), then resume from the atomic
     checkpoint — completed subgraphs are not rebuilt;
  3. elastic scaling: the same workload replayed at several worker counts.

    PYTHONPATH=src python examples/distributed_build.py
"""

import shutil
import tempfile
import time

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.pipeline import SOGAICBuilder, SOGAICConfig
from repro.distributed.cluster_sim import SimulatedCluster


def main() -> None:
    rng = np.random.default_rng(0)
    x = rng.normal(size=(12_000, 32)).astype(np.float32)
    cfg = SOGAICConfig(
        gamma=1_600, omega=4, eps=1.8, r=24, n_workers=8,
        sample_size=6_000, chunk_size=4_096,
    )

    # -- 1. hostile cluster -------------------------------------------------
    cluster = SimulatedCluster(
        cfg.n_workers, fail_prob=0.15, max_failures=5,
        straggler_prob=0.15, straggler_slowdown=5.0, seed=3,
    )
    t0 = time.time()
    index, rep = SOGAICBuilder(cfg).build(x, runner_wrapper=cluster.wrap)
    print(f"[1] hostile cluster: built in {time.time()-t0:.1f}s wall, "
          f"{cluster._failures} worker deaths absorbed, "
          f"graph components={rep.graph['n_components']}")

    # -- 2. crash + resume ---------------------------------------------------
    ckpt_dir = tempfile.mkdtemp(prefix="sogaic_")
    ckpt = CheckpointManager(ckpt_dir)

    class Crash(Exception):
        pass

    calls = {"n": 0}

    def crashing_wrapper(runner):
        def wrapped(task, wid):
            calls["n"] += 1
            if calls["n"] == 5:
                raise Crash("simulated process crash")
            return runner(task, wid)
        return wrapped

    try:
        SOGAICBuilder(cfg).build(x, ckpt=ckpt, runner_wrapper=crashing_wrapper)
    except Crash:
        done = sum(1 for i in range(64) if ckpt.exists(f"subgraph_{i}"))
        print(f"[2] crashed mid-build with {done} subgraphs checkpointed")
    t1 = time.time()
    index2, rep2 = SOGAICBuilder(cfg).build(x, ckpt=ckpt)
    print(f"[2] resumed and finished in {time.time()-t1:.1f}s "
          f"(stages done: {sorted(k for k in ['centroids','partition','build','merge'] if ckpt.stage_done(k))})")
    shutil.rmtree(ckpt_dir)

    # -- 3. elastic scaling ----------------------------------------------------
    from benchmarks.bench_scalability import simulate, partition_members

    members, _ = partition_members(n=20_000, gamma=1_000)
    members = [m for m in members if len(m)]
    base = simulate(members, 1)
    print("[3] elastic scaling (virtual makespans):")
    for w in [1, 4, 16, 64]:
        t = simulate(members, w)
        print(f"    {w:3d} workers: {t:9.1f}  speedup {base/t:6.2f}× "
              f"(efficiency {base/t/w:.2f})")


if __name__ == "__main__":
    main()
