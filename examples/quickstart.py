"""Quickstart: build a SOGAIC index on synthetic data and query it.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core.pipeline import SOGAICBuilder, SOGAICConfig
from repro.core.search import brute_force_topk, recall_at_k
from repro.data.datasets import generate_dataset


def main() -> None:
    # a SIFT-like dataset (manifold structure, LID ≈ 10) at laptop scale
    x, queries = generate_dataset("sift1m", n_override=10_000, n_query=100)

    cfg = SOGAICConfig(
        gamma=2_000,   # Γ — max vectors per subset (container memory bound)
        omega=4,       # Ω — max subsets per vector
        eps=1.8,       # ε — adaptive relaxation (paper-tuned)
        r=32,          # graph degree bound
        n_workers=8,   # virtual build workers
        sample_size=8_192,
        chunk_size=4_096,
    )
    index, report = SOGAICBuilder(cfg).build(x)

    print(f"Φ (partitions)       : {report.phi}")
    print(f"avg overlap          : {report.avg_overlap:.2f}  (Ω preset = {cfg.omega})")
    print(f"redundancy reduction : {1 - report.avg_overlap / cfg.omega:.1%}")
    print(f"build makespan       : {report.build_makespan:.2f}s "
          f"(virtual, {cfg.n_workers} workers)")
    print(f"merge makespan       : {report.merge_makespan:.2f}s")
    print(f"graph                : {report.graph}")

    ids, dists = index.search(queries, k=10, beam_l=64)
    _, gt = brute_force_topk(jnp.asarray(x), jnp.asarray(queries), 10)
    print(f"recall@10            : {recall_at_k(ids, np.asarray(gt)):.4f}")


if __name__ == "__main__":
    main()
