"""End-to-end driver: build a PQ-equipped index, persist it, then serve
batched ANN request waves — the deployment shape of the paper's system
(index construction feeding an online search engine).

    PYTHONPATH=src python examples/build_and_serve.py
"""

import tempfile
import time

import numpy as np
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.core.pipeline import SOGAICBuilder, SOGAICConfig, SOGAICIndex
from repro.core.pq import adc_distances, adc_lookup_tables
from repro.core.search import brute_force_topk, recall_at_k
from repro.data.datasets import generate_dataset


def main() -> None:
    x, _ = generate_dataset("vdd10b", n_override=8_000, n_query=0)
    x = x[:, :64]  # trim dim for CPU demo speed

    with tempfile.TemporaryDirectory() as td:
        ckpt = CheckpointManager(td, async_writes=True)
        cfg = SOGAICConfig(
            gamma=1_500, omega=4, eps=1.8, r=24, n_workers=8,
            pq_m=8,  # fused PQ encoding in the partition pass (Fig. 1c)
            sample_size=4_096, chunk_size=2_048,
        )
        t0 = time.time()
        index, rep = SOGAICBuilder(cfg).build(x, ckpt=ckpt)
        ckpt.close()
        print(f"build: {time.time()-t0:.1f}s wall  Φ={rep.phi} "
              f"overlap={rep.avg_overlap:.2f} graph={rep.graph}")

        # reload through the checkpoint (what a serving fleet would do)
        index = SOGAICIndex.load(CheckpointManager(td))

        # batched request waves
        rng = np.random.default_rng(7)
        n, d = index.x.shape
        lat = []
        rec = []
        for wave in range(6):
            q = index.x[rng.choice(n, 64)] + rng.normal(0, 0.03, (64, d)).astype(
                np.float32
            )
            t1 = time.perf_counter()
            ids, dists = index.search(q, k=10, beam_l=64)
            lat.append((time.perf_counter() - t1) * 1e3)
            _, gt = brute_force_topk(jnp.asarray(index.x), jnp.asarray(q), 10)
            rec.append(recall_at_k(ids, np.asarray(gt)))
        lat = np.array(lat[1:])  # first wave includes compile
        print(f"serve: p50={np.percentile(lat,50):.1f}ms "
              f"p99={np.percentile(lat,99):.1f}ms "
              f"qps={64/(lat.mean()/1e3):.0f} recall@10={np.mean(rec):.4f}")

        # PQ fast path: ADC approximate re-ranking table
        q = index.x[rng.choice(n, 4)]
        luts = adc_lookup_tables(jnp.asarray(q), index.pq_codebook)
        approx = np.asarray(adc_distances(luts, jnp.asarray(index.pq_codes)))
        print(f"ADC distance table: {approx.shape} "
              f"(≈{approx.nbytes/1e6:.1f} MB for {n} codes × {len(q)} queries)")


if __name__ == "__main__":
    main()
