"""Adaptive overlap factor — the paper's §3.2.1 headline.

The paper reports that with Ω=4 preset and ε=1.8, the adaptive assignment
lands at an average of 1.93 subsets/vector — a 51.8% reduction in
redundant build work vs the fixed-Ω baseline, while preserving recall.
This bench sweeps ε on a skewed (ISD3B-like) and a manifold (SIFT-like)
dataset and reports avg overlap + the reduction vs fixed Ω=4 assignment.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.kmeans import kmeans_fit
from repro.core.partition import PartitionConfig, estimate_num_partitions, partition_all
from repro.data.datasets import DATASETS


def run(out_rows: list[dict], *, quick: bool = False) -> None:
    n = 8_000 if quick else 20_000
    omega = 4
    for name in (["isd3b"] if quick else ["isd3b", "sift1m"]):
        spec = DATASETS[name]
        x = spec.generate(n, seed=2).astype(np.float32)
        gamma = n // 6
        phi = estimate_num_partitions(n, gamma, omega)
        cent = np.asarray(
            kmeans_fit(jax.random.PRNGKey(0), jnp.asarray(x[:8192]), phi, max_iters=12).centroids
        )
        for eps in ([1.8] if quick else [1.2, 1.5, 1.8, 2.5]):
            res = partition_all(
                x, cent,
                PartitionConfig(gamma=gamma, omega=omega, eps=eps, chunk_size=4096),
            )
            out_rows.append(dict(
                bench="overlap", dataset=name, eps=eps, omega=omega,
                avg_overlap=round(res.avg_overlap, 3),
                reduction_vs_fixed=round(1 - res.avg_overlap / omega, 3),
                max_subset=int(res.sizes.max()), gamma=gamma,
                fallbacks=res.fallback_count,
            ))
