"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run JSONL artifacts.

    PYTHONPATH=src python -m benchmarks.roofline > /tmp/roofline.md
"""

from __future__ import annotations

import json
import math
import os


def _lm_param_counts(cfg) -> tuple[float, float]:
    """(total, active) parameter counts for the LM configs."""
    import jax
    import jax.numpy as jnp

    from repro.models.transformer import init_lm_params

    shapes = jax.eval_shape(
        lambda k: init_lm_params(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    total = sum(math.prod(s.shape) for s in jax.tree.leaves(shapes))
    active = total
    if cfg.moe is not None:
        per_expert = 3 * cfg.d_model * cfg.moe.d_ff_expert * cfg.n_layers
        routed_total = cfg.moe.n_experts * per_expert
        routed_active = cfg.moe.top_k * per_expert
        active = total - routed_total + routed_active
    return float(total), float(active)


def _model_flops(cfg, shape, n_active: float) -> float | None:
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    if shape.kind == "decode":
        return 2.0 * n_active * shape.global_batch  # one token per sequence
    return None


def load(mesh: str, results_dir: str = "benchmarks/results") -> list[dict]:
    path = os.path.join(results_dir, f"dryrun_{mesh}.jsonl")
    rows = []
    with open(path) as f:
        for line in f:
            rows.append(json.loads(line))
    return rows


def dryrun_table(mesh: str) -> str:
    rows = load(mesh)
    out = [
        f"**Mesh `{mesh}`** — "
        + ("(2, 16, 16) pod×data×model, 512 chips" if mesh == "multi"
           else "(16, 16) data×model, 256 chips"),
        "",
        "| arch | shape | status | bottleneck | HBM/chip | fits 16G | "
        "collectives (MB/chip) |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] == "skipped":
            out.append(
                f"| {r['arch']} | {r['shape']} | **skip** | — | — | — | "
                f"{r['skip_reason'][:60]}… |"
            )
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | ERROR | — | — | — | {r['error'][:60]} |")
            continue
        coll = ", ".join(
            f"{k.replace('collective-','c-')}:{v['bytes']/1e6:.0f}"
            for k, v in r["collectives"].items() if v["bytes"]
        ) or "—"
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['bottleneck']} | "
            f"{r['peak_hbm_bytes']/2**30:.2f} GiB | "
            f"{'✓' if r['fits_hbm'] else '✗'} | {coll} |"
        )
    return "\n".join(out)


def roofline_table(mesh: str = "single") -> str:
    from repro.configs import get_config

    rows = load(mesh)
    out = [
        "| arch | shape | t_compute | t_memory | t_collective | bound | "
        "roofline frac | MODEL_FLOPS/HLO |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] != "ok":
            continue
        cfg = get_config(r["arch"])
        ratio = ""
        if cfg.family == "lm":
            shape = next(s for s in cfg.shapes if s.name == r["shape"])
            total, active = _lm_param_counts(cfg)
            mf = _model_flops(cfg, shape, active)
            if mf:
                hlo_global = r["flops_per_device"] * r["n_chips"]
                ratio = f"{mf / hlo_global:.2f}"
        frac = r["t_compute_s"] / max(
            r["t_compute_s"], r["t_memory_s"], r["t_collective_s"]
        )
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e} | "
            f"{r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} | "
            f"{r['bottleneck']} | {frac:.3f} | {ratio} |"
        )
    return "\n".join(out)


def main() -> None:
    print("## §Dry-run\n")
    for mesh in ("single", "multi"):
        print(dryrun_table(mesh))
        print()
    print("## §Roofline (single-pod, per-chip seconds; v5e: 197 TF/s bf16, "
          "819 GB/s HBM, 50 GB/s ICI)\n")
    print(roofline_table("single"))


if __name__ == "__main__":
    main()
