"""Kernel micro-bench: jitted oracle timings + interpret-mode validation.

On this CPU container the Pallas kernels execute in interpreter mode (not
representative of TPU timing), so the wall-clock numbers reported are the
jnp-oracle XLA-CPU timings for the three hot ops at pipeline-realistic
shapes; the Pallas path is asserted allclose at each shape.  TPU-side
performance is covered by the §Roofline analysis of the dry-run.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # µs


def run(out_rows: list[dict], *, quick: bool = False) -> None:
    rng = np.random.default_rng(0)
    shapes = [(1024, 4096, 128, 32)] if quick else [
        (1024, 4096, 128, 32), (2048, 8192, 256, 64),
    ]
    for m, n, d, k in shapes:
        q = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
        db = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))

        t_pair = _time(lambda a, b: ref.pairwise_l2_ref(a, b), q, db)
        t_topk = _time(lambda a, b: ref.l2_topk_ref(a, b, k), q, db)
        out_rows.append(dict(
            bench="kernels", op="pairwise_l2", m=m, n=n, d=d,
            us_per_call=round(t_pair, 1),
            derived=f"{2*m*n*d/t_pair/1e6:.1f}GFLOP/s_cpu",
        ))
        out_rows.append(dict(
            bench="kernels", op="l2_topk_fused", m=m, n=n, d=d,
            us_per_call=round(t_topk, 1),
            derived=f"hbm_bytes_saved={(m*n*4 - m*k*8)/1e6:.0f}MB_vs_unfused",
        ))
        # interpret-mode correctness at this exact shape (small slice — the
        # interpreter is pure Python)
        qs, dbs = q[:64], db[:512]
        got = ops.l2_topk(qs, dbs, k, impl="interpret")
        want = ref.l2_topk_ref(qs, dbs, k)
        assert np.allclose(np.asarray(got[0]), np.asarray(want[0]), rtol=1e-4, atol=1e-4)

    cb = jnp.asarray(rng.normal(size=(64, 256, 8)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(4096, 512)).astype(np.float32))
    t_pq = _time(lambda a, b: ref.pq_encode_ref(a, b), x, cb)
    out_rows.append(dict(
        bench="kernels", op="pq_encode", m=4096, n=64 * 256, d=512,
        us_per_call=round(t_pq, 1),
        derived=f"{4096*64/t_pq:.1f}Mcodes/s_cpu",
    ))
