"""Table 1 mirror: dataset stats + measured LID at bench scale.

Checks that the synthetic stand-ins land near the paper's reported local
intrinsic dimensionality (the hardness axis that drives the ISD3B/GloVe
failure modes in the baselines).
"""

from __future__ import annotations

from repro.data.datasets import DATASETS
from repro.data.lid import estimate_lid


def run(out_rows: list[dict], *, quick: bool = False) -> None:
    n = 4_000 if quick else 10_000
    for name, spec in DATASETS.items():
        x = spec.generate(n, seed=0)
        lid = estimate_lid(x, k=20, sample=512)
        out_rows.append(dict(
            bench="datasets", dataset=name, dim=spec.dim,
            paper_n_base=spec.n_base, paper_lid=spec.lid,
            measured_lid=round(lid, 1), bench_n=n,
        ))
