"""Scheduling quality — LPT vs naive assignment (paper §2.2).

The paper's load-balancing argument: sort-descending + least-loaded-first
keeps the makespan near the lower bound.  We compare LPT against random
and round-robin placement on Γ-bounded subset-size distributions (the
bound is what makes greedy sufficient — no BDSC/LSSP machinery needed).
"""

from __future__ import annotations

import numpy as np

from repro.core.scheduler import lpt_schedule, makespan_lower_bound


def _round_robin(costs, m):
    loads = np.zeros(m)
    for i, c in enumerate(costs):
        loads[i % m] += c
    return loads.max()


def _random(costs, m, seed=0):
    rng = np.random.default_rng(seed)
    loads = np.zeros(m)
    for c in costs:
        loads[rng.integers(m)] += c
    return loads.max()


def run(out_rows: list[dict], *, quick: bool = False) -> None:
    rng = np.random.default_rng(0)
    for skew_name, sizes in {
        "balanced": rng.uniform(0.8, 1.2, 256),
        "zipf_capped": np.minimum(rng.pareto(1.1, 256) + 0.5, 4.0),  # Γ cap
    }.items():
        for m in [8, 32, 128]:
            _, lpt = lpt_schedule(sizes, m)
            lb = makespan_lower_bound(sizes, m)
            out_rows.append(dict(
                bench="scheduling", dist=skew_name, workers=m,
                lpt=round(lpt, 3), round_robin=round(_round_robin(sizes, m), 3),
                random=round(_random(sizes, m), 3), lower_bound=round(lb, 3),
                lpt_over_lb=round(lpt / lb, 4),
            ))
