"""Benchmark driver — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Prints ``name,us_per_call,derived``-style CSV rows (full row dicts) and
writes benchmarks/results/bench_results.json.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time


BENCHES = ["datasets", "scheduling", "overlap", "scalability", "kernels", "construction"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None, choices=BENCHES)
    args = ap.parse_args()

    from benchmarks import (
        bench_construction,
        bench_datasets,
        bench_kernels,
        bench_overlap,
        bench_scalability,
        bench_scheduling,
    )

    mods = {
        "datasets": bench_datasets,
        "scheduling": bench_scheduling,
        "overlap": bench_overlap,
        "scalability": bench_scalability,
        "kernels": bench_kernels,
        "construction": bench_construction,
    }
    rows: list[dict] = []
    for name in BENCHES:
        if args.only and name != args.only:
            continue
        t0 = time.time()
        print(f"## bench: {name}", flush=True)
        mods[name].run(rows, quick=args.quick)
        print(f"## bench {name} done in {time.time()-t0:.1f}s", flush=True)

    # CSV-ish output: header per bench group
    last = None
    for r in rows:
        keys = list(r.keys())
        if keys != last:
            print(",".join(keys))
            last = keys
        print(",".join(str(r[k]) for k in keys))

    os.makedirs("benchmarks/results", exist_ok=True)
    with open("benchmarks/results/bench_results.json", "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\n{len(rows)} result rows → benchmarks/results/bench_results.json")


if __name__ == "__main__":
    main()
