"""Construction time vs worker count — the paper's Fig. 3 (right column).

The paper's scalability claim: SOGAIC keeps a near-linear time/resource
relationship while DiskANN's sequential merge saturates.  We reproduce the
*scheduling* half exactly (the compute half is the measured linear cost
model): partition a dataset with Algorithm 1, predict per-subset build
costs with the fitted linear model, then replay both execution plans on
the virtual cluster while sweeping the worker count:

  sogaic       LPT-scheduled builds + tree merge rounds (each round
               parallel across workers)
  sequential   all builds on one box, chain merge (DiskANN-style)

Speedup ratio vs workers is the reported curve; ≥0.7·ideal at 64 workers
is the paper-faithful 'near-linear' check used by the tests.
"""

from __future__ import annotations

import numpy as np

from repro.core.merge import agglomerative_schedule, overlap_counts
from repro.core.partition import PartitionConfig, estimate_num_partitions, partition_all
from repro.core.scheduler import ClusterScheduler, ScheduledTask, lpt_schedule
from repro.data.datasets import DATASETS
from repro.distributed.cluster_sim import SimulatedCluster

import jax
import jax.numpy as jnp

from repro.core.kmeans import kmeans_fit


def simulate(members, n_workers: int, *, c1: float = 1.0, seed: int = 0,
             fail_prob: float = 0.0, straggler_prob: float = 0.0):
    """Virtual makespan of the SOGAIC plan on n_workers.

    Merge cost model matches the paper (§2.2) and our merge_pair: "the
    computationally intensive part involves neighbor selection for
    overlapping regions, while disjoint parts carry over without
    additional computation" — cost ∝ overlap rows (the re-pruned set) plus
    a small linear carry-over term (adjacency copy / exchange bytes).
    """
    sizes = np.array([len(m) for m in members], float)
    cluster = SimulatedCluster(
        n_workers, seed=seed, fail_prob=fail_prob,
        straggler_prob=straggler_prob, straggler_slowdown=4.0,
        max_failures=3,
    )
    sched = ClusterScheduler(n_workers, max_attempts=6)
    tasks = [ScheduledTask(i, cost=c1 * s) for i, s in enumerate(sizes)]
    build = sched.run(tasks, cluster.cost_runner())["makespan"]

    ov = overlap_counts(members)
    rounds = agglomerative_schedule(sizes, ov)
    merge = 0.0
    nid = len(members)
    node_sizes = {i: s for i, s in enumerate(sizes)}
    ov_est = {(min(i, j), max(i, j)): float(ov[i, j])
              for i in range(len(members)) for j in range(i + 1, len(members))}

    def get_ov(a, b):
        return ov_est.get((min(a, b), max(a, b)), 0.0)

    carry = 0.01  # copy/exchange per row vs full prune per overlap row
    quantum = 512.0  # rows per merge subtask — merge_pair's prune is
    # row-blocked (prune_candidate_lists) and the distributed merge_step
    # shards rows across the mesh, so a big merge is a *malleable* task:
    # it splits into row-block subtasks that fill idle workers.
    tid = 100_000
    for rnd in rounds:
        sched_r = ClusterScheduler(n_workers, max_attempts=6)
        tasks_r = []
        for a, b in rnd:
            olap = get_ov(a, b)
            cost = c1 * (olap + carry * (node_sizes[a] + node_sizes[b]))
            n_sub = max(1, int(np.ceil(cost / quantum)))
            for _ in range(n_sub):
                tasks_r.append(
                    ScheduledTask(tid, cost=cost / n_sub, priority=olap)
                )
                tid += 1
            node_sizes[nid] = node_sizes[a] + node_sizes[b] - olap
            for c in list(node_sizes):
                if c not in (a, b, nid):
                    ov_est[(min(c, nid), max(c, nid))] = get_ov(a, c) + get_ov(b, c)
            nid += 1
        merge += sched_r.run(tasks_r, cluster.cost_runner())["makespan"]
    return build + merge


def simulate_sequential(members, *, c1: float = 1.0):
    """DiskANN-style: one worker builds everything, chain merge."""
    sizes = np.array([len(m) for m in members], float)
    build = c1 * sizes.sum()
    acc = sizes[0]
    merge = 0.0
    for s in sizes[1:]:
        merge += 0.3 * c1 * (acc + s)
        acc += s
    return build + merge


def partition_members(n: int = 40_000, d: int = 64, gamma: int = 1_000, seed: int = 0):
    spec = DATASETS["vdd10b"]
    x = spec.generate(n, seed=seed).astype(np.float32)[:, :d]
    phi = estimate_num_partitions(n, gamma, 4)
    cent = np.asarray(
        kmeans_fit(jax.random.PRNGKey(seed), jnp.asarray(x[:8192]), phi, max_iters=10).centroids
    )
    res = partition_all(x, cent, PartitionConfig(gamma=gamma, omega=4, eps=1.8, chunk_size=8192))
    return res.all_members(), res


def run(out_rows: list[dict], *, quick: bool = False) -> None:
    members, res = partition_members(n=20_000 if quick else 40_000)
    members = [m for m in members if len(m)]
    seq = simulate_sequential(members)
    base_1 = simulate(members, 1)
    for w in ([1, 4, 16, 64] if quick else [1, 2, 4, 8, 16, 32, 64, 128]):
        t = simulate(members, w)
        out_rows.append(dict(
            bench="scalability", workers=w, method="sogaic",
            vtime=round(t, 1), speedup=round(base_1 / t, 2),
            ideal=w, efficiency=round(base_1 / t / w, 3),
        ))
    out_rows.append(dict(
        bench="scalability", workers=1, method="sequential_diskann_like",
        vtime=round(seq, 1), speedup=1.0, ideal=1, efficiency=1.0,
    ))
    # fault tolerance: failures + stragglers barely move the makespan
    t_faulty = simulate(members, 32, fail_prob=0.05, straggler_prob=0.1, seed=3)
    t_clean = simulate(members, 32)
    out_rows.append(dict(
        bench="scalability", workers=32, method="sogaic_faulty_cluster",
        vtime=round(t_faulty, 1), speedup=round(base_1 / t_faulty, 2),
        ideal=32, efficiency=round(t_clean / t_faulty, 3),
    ))
