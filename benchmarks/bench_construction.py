"""Construction time vs recall — the paper's Fig. 3 (left column).

Compares, at bench scale on each Table-1-mirror dataset:

  sogaic          the full pipeline (adaptive overload-aware partitioning,
                  LPT-parallel builds, agglomerative tree merge)
  diskann_like    DiskANN's divide-and-conquer as described in the paper:
                  fixed closest-ℓ assignment (no overload bound — subsets
                  can blow past Γ) + sequential merge chain on one worker
  global          single-shot whole-dataset build (quality upper bound,
                  no partitioning — the thing that cannot scale)

Time is the *virtual parallel* time (host stage wall time + scheduler
makespans) so the comparison reflects the cluster execution model, and
recall@10 is measured against exact ground truth.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.graph import build_subgraph, find_medoid
from repro.core.kmeans import pairwise_sq_l2
from repro.core.merge import SubGraph, agglomerative_schedule, merge_pair, overlap_counts
from repro.core.pipeline import BuildReport, SOGAICBuilder, SOGAICConfig
from repro.core.scheduler import ClusterScheduler, ScheduledTask
from repro.core.search import beam_search, brute_force_topk, recall_at_k
from repro.data.datasets import DATASETS


def _search_recall(x, adj, q, gt, beam_l=64):
    res = beam_search(
        jnp.asarray(x, jnp.float32), jnp.asarray(adj), jnp.asarray(q, jnp.float32),
        find_medoid(jnp.asarray(x, jnp.float32)), k=10, beam_l=beam_l, max_hops=96,
    )
    return recall_at_k(np.asarray(res.ids), gt)


def _diskann_like(x, cfg: SOGAICConfig):
    """Fixed closest-2 assignment + sequential builds + chain merge."""
    n, d = x.shape
    t0 = time.perf_counter()
    phi = max(2, -(-2 * n // cfg.gamma))
    from repro.core.kmeans import kmeans_fit
    import jax

    cent = kmeans_fit(
        jax.random.PRNGKey(0), jnp.asarray(x[: cfg.sample_size], jnp.float32), phi,
        max_iters=cfg.kmeans_iters,
    ).centroids
    d2 = np.asarray(pairwise_sq_l2(jnp.asarray(x, jnp.float32), cent))
    closest2 = np.argsort(d2, axis=1)[:, :2]  # fixed ℓ=2, no Γ bound
    members = [np.nonzero((closest2 == j).any(1))[0] for j in range(phi)]
    members = [m for m in members if len(m)]
    t_partition = time.perf_counter() - t0

    # sequential build (single high-resource worker — the paper's critique)
    build_times = []
    graphs = []
    for m in members:
        t1 = time.perf_counter()
        adj = build_subgraph(jnp.asarray(x[m], jnp.float32), cfg.r, alpha=cfg.alpha)
        adj.block_until_ready()
        build_times.append(time.perf_counter() - t1)
        graphs.append(SubGraph(ids=m.astype(np.int64), adj=np.asarray(adj)))
    # sequential chain merge (O(n) depth, one worker)
    t2 = time.perf_counter()
    g = graphs[0]
    merge_time = 0.0
    for nxt in graphs[1:]:
        t3 = time.perf_counter()
        g = merge_pair(g, nxt, x, alpha=cfg.alpha)
        merge_time += time.perf_counter() - t3
    total = t_partition + sum(build_times) + merge_time
    max_subset = max(len(m) for m in members)
    return g, total, max_subset


def run(out_rows: list[dict], *, n: int = 12_000, quick: bool = False) -> None:
    datasets = ["sift1m", "glove", "isd3b"] if not quick else ["sift1m"]
    for name in datasets:
        spec = DATASETS[name]
        x = spec.generate(n + 200, seed=1)
        x, q = x[:n], x[n : n + 100]
        gt = np.asarray(brute_force_topk(jnp.asarray(x), jnp.asarray(q), 10)[1])
        gamma = n // 8

        cfg = SOGAICConfig(
            gamma=gamma, omega=4, eps=1.8, chunk_size=4096, r=24,
            n_workers=8, sample_size=min(8192, n), kmeans_iters=15,
        )
        idx, rep = SOGAICBuilder(cfg).build(x)
        t_sogaic = rep.total_parallel_time()
        # SOGAIC serves with centroid-routed entries (the centroids are the
        # partitioning stage's by-product — part of the system under test)
        ids_s, _ = idx.search(q, 10, beam_l=64)
        r_sogaic = recall_at_k(ids_s, gt)
        out_rows.append(dict(
            bench="construction", dataset=name, method="sogaic",
            time_s=round(t_sogaic, 3), recall_at_10=round(r_sogaic, 4),
            avg_overlap=round(rep.avg_overlap, 3), max_subset=int(rep.graph["n"] and max(1, gamma)),
        ))

        g, t_diskann, max_subset = _diskann_like(x, cfg)
        r_diskann = _search_recall(x, g.adj, q, gt)
        out_rows.append(dict(
            bench="construction", dataset=name, method="diskann_like",
            time_s=round(t_diskann, 3), recall_at_10=round(r_diskann, 4),
            avg_overlap=2.0, max_subset=int(max_subset),
        ))

        t4 = time.perf_counter()
        adj_g = build_subgraph(jnp.asarray(x, jnp.float32), cfg.r)
        adj_g.block_until_ready()
        t_global = time.perf_counter() - t4
        r_global = _search_recall(x, np.asarray(adj_g), q, gt)
        out_rows.append(dict(
            bench="construction", dataset=name, method="global",
            time_s=round(t_global, 3), recall_at_10=round(r_global, 4),
            avg_overlap=1.0, max_subset=n,
        ))
