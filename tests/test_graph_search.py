"""Subgraph construction + beam search: correctness, invariants, recall."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import (
    build_knn_graph,
    build_subgraph,
    find_medoid,
    graph_stats,
    prune_candidate_lists,
)
from repro.core.search import beam_search, brute_force_topk, recall_at_k


def test_knn_graph_exact():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(300, 16)).astype(np.float32))
    d, idx = build_knn_graph(x, 10, block_q=64)
    # check rows against brute force (excluding self)
    gt_d, gt_i = brute_force_topk(x, x, 11)
    np.testing.assert_allclose(np.asarray(d), np.asarray(gt_d[:, 1:]), rtol=1e-4, atol=1e-4)


def test_knn_graph_n_valid_masks_pads():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(100, 8)).astype(np.float32)
    pad = np.full((28, 8), 1e5, np.float32)
    xp = jnp.asarray(np.concatenate([x, pad]))
    d, idx = build_knn_graph(xp, 5, block_q=32, n_valid=jnp.int32(100))
    idx = np.asarray(idx)[:100]
    assert (idx < 100).all(), "padding rows must never be neighbors"


def test_robust_prune_diversity():
    """α-pruning: among selected neighbors, no candidate dominates another
    (Vamana invariant: for selected a,b with d(p,a) ≤ d(p,b):
    α·d(a,b) > d(p,b))."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(200, 8)).astype(np.float32))
    cand = jnp.asarray(
        np.stack([rng.choice(200, 32, replace=False) for _ in range(50)]).astype(np.int32)
    )
    nodes = jnp.arange(50, dtype=jnp.int32)
    alpha = 1.2
    adj = np.asarray(prune_candidate_lists(x, nodes, cand, 8, alpha=alpha, block=16))
    xn = np.asarray(x)
    for p in range(50):
        sel = [v for v in adj[p] if v >= 0]
        dp = {v: np.linalg.norm(xn[p] - xn[v]) for v in sel}
        sel_sorted = sorted(sel, key=lambda v: dp[v])
        for i, a in enumerate(sel_sorted):
            for b in sel_sorted[i + 1 :]:
                dab = np.linalg.norm(xn[a] - xn[b])
                assert alpha * dab > dp[b] - 1e-4, (p, a, b)


def test_build_subgraph_invariants():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(500, 16)).astype(np.float32))
    adj = np.asarray(build_subgraph(x, 16))
    assert adj.shape == (500, 16)
    deg = (adj >= 0).sum(1)
    assert deg.min() >= 1
    stats = graph_stats(adj)
    assert stats["n_components"] == 1, "reverse pass must connect the graph"
    # no self loops / no out-of-range
    assert (adj != np.arange(500)[:, None]).all()
    assert adj.max() < 500


@pytest.mark.parametrize("n,d", [(800, 16), (1500, 32)])
def test_recall(n, d):
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    adj = build_subgraph(x, 24)
    q = jnp.asarray(rng.normal(size=(40, d)).astype(np.float32))
    _, gt = brute_force_topk(x, q, 10)
    res = beam_search(x, adj, q, find_medoid(x), k=10, beam_l=64, max_hops=96)
    r = recall_at_k(np.asarray(res.ids), np.asarray(gt))
    assert r >= 0.9, f"recall@10 {r}"


def test_beam_search_returns_sorted_unique():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(400, 8)).astype(np.float32))
    adj = build_subgraph(x, 12)
    q = jnp.asarray(rng.normal(size=(10, 8)).astype(np.float32))
    res = beam_search(x, adj, q, find_medoid(x), k=8, beam_l=32, max_hops=64)
    ids = np.asarray(res.ids)
    dists = np.asarray(res.dists)
    for i in range(10):
        valid = ids[i][ids[i] >= 0]
        assert len(set(valid.tolist())) == len(valid), "duplicates in results"
        dd = dists[i][np.isfinite(dists[i])]
        assert (np.diff(dd) >= -1e-6).all(), "results must be distance-sorted"


@hypothesis.given(
    n=st.integers(50, 400), d=st.integers(2, 24), r=st.integers(4, 24),
    seed=st.integers(0, 2**16),
)
@hypothesis.settings(max_examples=10, deadline=None)
def test_property_build_degree_bound(n, d, r, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    adj = np.asarray(build_subgraph(x, r))
    assert adj.shape == (n, r)
    assert ((adj >= -1) & (adj < n)).all()
    assert (adj != np.arange(n)[:, None]).all(), "no self loops"


def test_vamana_refine_improves_or_preserves_recall():
    from repro.core.graph import vamana_refine

    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(1200, 24)).astype(np.float32))
    # deliberately weak base graph: tiny candidate pool
    adj0 = build_subgraph(x, 12, knn_k=14)
    adj1 = vamana_refine(x, adj0, 12, beam_l=32, max_hops=32)
    q = jnp.asarray(rng.normal(size=(30, 24)).astype(np.float32))
    _, gt = brute_force_topk(x, q, 10)
    med = find_medoid(x)
    r0 = recall_at_k(
        np.asarray(beam_search(x, adj0, q, med, k=10, beam_l=48, max_hops=64).ids),
        np.asarray(gt),
    )
    r1 = recall_at_k(
        np.asarray(beam_search(x, adj1, q, med, k=10, beam_l=48, max_hops=64).ids),
        np.asarray(gt),
    )
    assert r1 >= r0 - 0.02, (r0, r1)
    assert np.asarray(adj1).shape == (1200, 12)
