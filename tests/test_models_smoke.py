"""Per-architecture smoke tests: reduced same-family configs, one forward /
train step on CPU, output shapes + finiteness (deliverable (f))."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models.gnn import init_gat_params, make_random_graph
from repro.models.recsys import init_recsys_params
from repro.models.transformer import (
    init_lm_params,
    lm_decode_step,
    lm_prefill,
)
from repro.training import init_adamw, make_gnn_train_step, make_lm_train_step, make_recsys_train_step

LM_ARCHS = [
    "deepseek-v2-236b",
    "moonshot-v1-16b-a3b",
    "llama3.2-3b",
    "smollm-360m",
    "phi3-mini-3.8b",
]
RECSYS_ARCHS = ["deepfm", "xdeepfm", "fm", "two-tower-retrieval"]


def test_registry_complete():
    expected = set(LM_ARCHS + RECSYS_ARCHS + ["gat-cora", "sogaic-vdd10b"])
    assert expected <= set(list_archs())


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_lm_params(key, cfg)
    step = make_lm_train_step(cfg, lr=1e-3)
    opt = init_adamw(params, moment_dtype=cfg.moment_dtype)
    toks = jax.random.randint(key, (2, 64), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    losses = []
    for _ in range(3):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], f"loss must decrease: {losses}"

    # serve path: prefill then one decode step, shape + finiteness
    logits, cache = lm_prefill(params, toks[:, :-1], cfg)
    assert logits.shape == (2, cfg.vocab)
    if cfg.attn == "mla":
        pad = jnp.zeros(
            (cfg.n_layers, 2, 64, cfg.mla_kv_lora + cfg.qk_rope_dim), jnp.float32
        ).at[:, :, :63].set(cache)
    else:
        pad = jnp.zeros(
            (2, cfg.n_layers, 2, 64, cfg.n_kv_heads, cfg.d_head), jnp.float32
        ).at[:, :, :, :63].set(cache)
    dec, new_cache = lm_decode_step(params, pad, toks[:, -1], jnp.int32(63), cfg)
    assert dec.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(dec)))
    assert new_cache.shape == pad.shape


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_microbatch_equivalence(arch):
    """mb>1 grad accumulation ≈ mb=1 (same loss trajectory, ample capacity)."""
    cfg = get_config(arch).reduced()
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    key = jax.random.PRNGKey(1)
    params = init_lm_params(key, cfg)
    toks = jax.random.randint(key, (4, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}

    out = {}
    for mb in (1, 2):
        c = dataclasses.replace(cfg, microbatches=mb)
        p = jax.tree.map(lambda a: a, params)
        opt = init_adamw(p, moment_dtype=cfg.moment_dtype)
        p, opt, m = make_lm_train_step(c, lr=1e-3)(p, opt, batch)
        out[mb] = (float(m["loss"]), p)
    assert abs(out[1][0] - out[2][0]) < 1e-3
    # params after one step agree to accumulation tolerance
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), out[1][1], out[2][1])
    assert max(jax.tree.leaves(d)) < 5e-3


def test_gnn_smoke():
    cfg = get_config("gat-cora").reduced()
    g = make_random_graph(120, 500, 12, 5, seed=1)
    params = init_gat_params(jax.random.PRNGKey(0), cfg, 12, 5)
    step = make_gnn_train_step(cfg, n_classes=5)
    opt = init_adamw(params)
    batch = {
        "feats": jnp.asarray(g["feats"]), "src": jnp.asarray(g["src"]),
        "dst": jnp.asarray(g["dst"]), "labels": jnp.asarray(g["labels"]),
        "mask": jnp.ones(120, jnp.float32),
    }
    l0 = None
    for i in range(8):
        params, opt, m = step(params, opt, batch)
        l0 = l0 if l0 is not None else float(m["loss"])
    assert float(m["loss"]) < l0


def test_gnn_minibatch_sampled():
    cfg = get_config("gat-cora").reduced()
    from repro.models.gnn import neighbor_sample

    g = make_random_graph(500, 4000, 8, 4, seed=2)
    block = neighbor_sample(g, np.arange(16), (4, 3), seed=0)
    assert block["src"].shape == block["dst"].shape
    n_max = block["nodes"].shape[0]
    params = init_gat_params(jax.random.PRNGKey(0), cfg, 8, 4)
    feats = jnp.asarray(
        np.where(
            (block["nodes"] >= 0)[:, None], g["feats"][np.maximum(block["nodes"], 0)], 0
        ).astype(np.float32)
    )
    from repro.models.gnn import gat_forward

    logits = gat_forward(
        params, feats, jnp.asarray(block["src"]), jnp.asarray(block["dst"]),
        cfg, n_classes=4,
    )
    assert logits.shape == (n_max, 4)
    seed_logits = logits[jnp.asarray(block["seeds"])]
    assert bool(jnp.all(jnp.isfinite(seed_logits)))


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke(arch):
    cfg = get_config(arch).reduced()
    params = init_recsys_params(jax.random.PRNGKey(0), cfg)
    opt = init_adamw(params)
    step = make_recsys_train_step(cfg)
    rng = np.random.default_rng(0)
    offs = np.concatenate([[0], np.cumsum(cfg.vocab_sizes)[:-1]])
    sparse = jnp.asarray(
        (rng.integers(0, 20, (16, cfg.n_sparse)) + offs[: cfg.n_sparse]).astype(np.int32)
    )
    dense = jnp.asarray(rng.normal(size=(16, cfg.n_dense)).astype(np.float32))
    if cfg.model == "two_tower":
        batch = {"sparse": sparse, "dense": dense,
                 "item_ids": jnp.asarray(rng.integers(0, cfg.n_items, 16).astype(np.int32))}
    else:
        batch = {"sparse": sparse, "dense": dense,
                 "labels": jnp.asarray(rng.integers(0, 2, 16).astype(np.int32))}
    l0 = None
    for _ in range(5):
        params, opt, m = step(params, opt, batch)
        l0 = l0 if l0 is not None else float(m["loss"])
    assert float(m["loss"]) < l0


def test_fm_sum_square_trick():
    """FM via ½((Σv)²−Σv²) equals the explicit pairwise sum."""
    from repro.models.recsys import _fm_interaction

    rng = np.random.default_rng(3)
    emb = jnp.asarray(rng.normal(size=(4, 6, 5)).astype(np.float32))
    got = np.asarray(_fm_interaction(emb))
    want = np.zeros(4, np.float32)
    e = np.asarray(emb)
    for b in range(4):
        for i in range(6):
            for j in range(i + 1, 6):
                want[b] += float(e[b, i] @ e[b, j])
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_embedding_bag():
    from repro.models.embedding import embedding_bag

    table = jnp.asarray(np.arange(20, dtype=np.float32).reshape(10, 2))
    idx = jnp.asarray([0, 1, 2, -1, 3], jnp.int32)
    seg = jnp.asarray([0, 0, 1, 1, 1], jnp.int32)
    out = np.asarray(embedding_bag(table, idx, seg, 2))
    np.testing.assert_allclose(out[0], [0 + 2, 1 + 3])
    np.testing.assert_allclose(out[1], [4 + 6, 5 + 7])  # -1 masked
    mean = np.asarray(embedding_bag(table, idx, seg, 2, mode="mean"))
    np.testing.assert_allclose(mean[1], [(4 + 6) / 2, (5 + 7) / 2])
