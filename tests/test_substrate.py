"""Checkpoint manager, data pipeline, PQ, k-means, optimizer."""

import os
import threading

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core.kmeans import kmeans_fit, pairwise_sq_l2
from repro.core.pq import adc_distances, adc_lookup_tables, pq_decode, pq_encode, pq_train
from repro.data import ChunkLoader, estimate_lid, generate_dataset, make_planted_manifold
from repro.training.optimizer import adamw_update, init_adamw


def test_checkpoint_roundtrip(tmp_path):
    ck = CheckpointManager(str(tmp_path))
    a = np.random.default_rng(0).normal(size=(10, 3))
    ck.save_array("a", a)
    np.testing.assert_array_equal(ck.load_array("a"), a)
    ck.save_arrays("z", x=a, y=a * 2)
    z = ck.load_arrays("z")
    np.testing.assert_array_equal(z["y"], a * 2)
    ck.save_json("meta", {"k": 1})
    assert ck.load_json("meta") == {"k": 1}
    ck.mark_stage("s1", foo=3)
    assert ck.stage_done("s1") and not ck.stage_done("s2")
    # a fresh manager sees the same manifest (atomic persistence)
    ck2 = CheckpointManager(str(tmp_path))
    assert ck2.stage_done("s1") and ck2.stage_meta("s1")["foo"] == 3


def test_checkpoint_async(tmp_path):
    ck = CheckpointManager(str(tmp_path), async_writes=True)
    for i in range(5):
        ck.save_array(f"a{i}", np.full((4,), i))
    ck.close()
    for i in range(5):
        np.testing.assert_array_equal(ck.load_array(f"a{i}"), np.full((4,), i))


def test_chunk_loader_sharded():
    x = np.arange(100 * 4, dtype=np.float32).reshape(100, 4)
    seen = []
    for host in range(2):
        for ci, lo, hi, chunk, valid in ChunkLoader(x, 16, host_id=host, n_hosts=2):
            seen.append((lo, hi))
            np.testing.assert_array_equal(chunk[: hi - lo], x[lo:hi])
            assert valid[: hi - lo].all()
            assert not valid[hi - lo :].any()
    covered = sorted(seen)
    assert covered[0][0] == 0 and covered[-1][1] == 100
    total = sum(hi - lo for lo, hi in seen)
    assert total == 100


def test_lid_tracks_intrinsic_dim():
    lo = make_planted_manifold(3000, 64, intrinsic_dim=4, seed=0)
    hi = make_planted_manifold(3000, 64, intrinsic_dim=24, seed=0)
    assert estimate_lid(lo, sample=256) < estimate_lid(hi, sample=256)


def test_datasets_registry():
    x, q = generate_dataset("sift1m", n_override=500, n_query=16)
    assert x.shape == (500, 128) and q.shape == (16, 128)


def test_kmeans_clusters():
    rng = np.random.default_rng(0)
    centers = rng.normal(size=(5, 8)) * 10
    x = (centers[rng.integers(0, 5, 1000)] + rng.normal(size=(1000, 8)) * 0.1).astype(
        np.float32
    )
    st_ = kmeans_fit(jax.random.PRNGKey(0), jnp.asarray(x), 5)
    assert float(st_.inertia) < 0.5
    # recovered centroids ≈ true centers (match by nearest)
    c = np.asarray(st_.centroids)
    d = ((c[:, None, :] - centers[None]) ** 2).sum(-1)
    assert (d.min(1) < 1.0).all()


def test_kmeans_minibatch():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(2000, 4)).astype(np.float32)
    st_ = kmeans_fit(jax.random.PRNGKey(0), jnp.asarray(x), 8, batch_size=256, max_iters=30)
    assert np.isfinite(float(st_.inertia))


def test_pq_roundtrip_and_adc():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2000, 32)).astype(np.float32))
    cb = pq_train(jax.random.PRNGKey(0), x, 8, iters=10)
    codes = pq_encode(x, cb)
    assert codes.shape == (2000, 8) and codes.dtype == jnp.uint8
    xr = pq_decode(codes, cb)
    mse = float(jnp.mean((xr - x) ** 2))
    assert mse < float(jnp.mean(x**2)) * 0.6, "PQ must reduce energy error"
    q = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
    luts = adc_lookup_tables(q, cb)
    approx = np.asarray(adc_distances(luts, codes))
    exact = np.asarray(pairwise_sq_l2(q, x))
    corr = np.corrcoef(approx.ravel(), exact.ravel())[0, 1]
    assert corr > 0.8, f"ADC distances must track exact ({corr})"


def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([4.0, -3.0])}
    opt = init_adamw(params)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(params, grads, opt, lr=5e-2, weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_adamw_bf16_moments():
    params = {"w": jnp.ones((8,), jnp.bfloat16)}
    opt = init_adamw(params, moment_dtype="bfloat16")
    assert opt.m["w"].dtype == jnp.bfloat16
    params, opt, gnorm = adamw_update(params, {"w": jnp.ones((8,), jnp.bfloat16)}, opt)
    assert params["w"].dtype == jnp.bfloat16
    assert np.isfinite(float(gnorm))


@hypothesis.given(
    n=st.integers(20, 200), d=st.integers(2, 16), m=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 1000),
)
@hypothesis.settings(max_examples=10, deadline=None)
def test_property_pq_codes_in_range(n, d, m, seed):
    d = d * m  # divisible
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(max(n, 300), d)).astype(np.float32))
    cb = pq_train(jax.random.PRNGKey(seed), x, m, n_codes=16, iters=3)
    codes = np.asarray(pq_encode(x[:n], cb))
    assert codes.shape == (n, m)
    assert (codes < 16).all()
