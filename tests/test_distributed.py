"""Multi-device tests (8 simulated host devices, run in a subprocess so the
main pytest process keeps seeing 1 device)."""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_test_mesh
from repro.distributed.steps import (
    make_assign_step, make_knn_step, make_build_step, make_merge_step,
    make_pq_encode_step,
)
from repro.core.search import brute_force_topk

out = {}
mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
rng = np.random.default_rng(0)

# assign: invariants under sharding
fn, _ = make_assign_step(mesh, omega=3, gamma=50, eps=1.6, k_cand=8)
x = rng.normal(size=(64, 16)).astype(np.float32)
cent = rng.normal(size=(16, 16)).astype(np.float32)
kept, cand, dist, added = fn(x, cent, np.zeros(16, np.int32))
kept = np.asarray(kept)
out["assign_all_assigned"] = bool((kept.sum(1) >= 1).all())
out["assign_omega_bound"] = bool((kept.sum(1) <= 3).all())
out["assign_added_consistent"] = int(np.asarray(added).sum()) == int(kept.sum())

# knn: exact match vs brute force
fn2, _ = make_knn_step(mesh, k=8)
db = rng.normal(size=(128, 16)).astype(np.float32)
dd, ii = fn2(x, db)
gtd, gti = brute_force_topk(jnp.asarray(db), jnp.asarray(x), 8)
out["knn_exact"] = bool((np.sort(np.asarray(ii), 1) == np.sort(np.asarray(gti), 1)).all())

# build: one subset per device
fn3, _ = make_build_step(mesh, r=8)
xs = rng.normal(size=(8, 64, 16)).astype(np.float32)
adj = np.asarray(fn3(xs, np.full((8,), 64, np.int32)))
out["build_shape"] = list(adj.shape) == [8, 64, 8]
out["build_no_self"] = bool(all((adj[i] != np.arange(64)[:, None]).all() for i in range(8)))

# merge + pq
fn4, _ = make_merge_step(mesh, r=8)
rows = fn4(rng.normal(size=(256, 16)).astype(np.float32),
           np.arange(64, dtype=np.int32),
           rng.integers(0, 256, size=(64, 16)).astype(np.int32))
out["merge_shape"] = list(np.asarray(rows).shape) == [64, 8]

fn5, _ = make_pq_encode_step(mesh)
cb = rng.normal(size=(4, 16, 4)).astype(np.float32)
codes = np.asarray(fn5(x, cb))
from repro.kernels import ref
want = np.asarray(ref.pq_encode_ref(jnp.asarray(x), jnp.asarray(cb)))
out["pq_match"] = bool((codes == want).all())

# grad compression: psum parity within tolerance + error feedback sanity
from repro.training.grad_compression import compressed_psum, apply_error_feedback
g = rng.normal(size=(32, 16)).astype(np.float32)

def body(gl):
    return compressed_psum(gl, ("pod", "data"), "bf16")

comp = jax.jit(jax.shard_map(body, mesh=mesh, in_specs=P(("pod","data"), None),
                             out_specs=P(("pod","data"), None), check_vma=False))(g)
# exact psum for comparison
def body2(gl):
    return jax.lax.psum(gl, ("pod", "data"))
exact = jax.jit(jax.shard_map(body2, mesh=mesh, in_specs=P(("pod","data"), None),
                              out_specs=P(("pod","data"), None), check_vma=False))(g)
rel = float(np.abs(np.asarray(comp) - np.asarray(exact)).max() /
            (np.abs(np.asarray(exact)).max() + 1e-9))
out["compressed_psum_close"] = rel < 0.02

deq, resid = apply_error_feedback(jnp.asarray(g), jnp.zeros_like(g), "int8")
out["error_feedback_residual_small"] = float(np.abs(np.asarray(resid)).max()) < 0.05

# production mesh constructors (shape only; 8 devices < 256 so just names)
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True, env=env,
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize(
    "key",
    [
        "assign_all_assigned",
        "assign_omega_bound",
        "assign_added_consistent",
        "knn_exact",
        "build_shape",
        "build_no_self",
        "merge_shape",
        "pq_match",
        "compressed_psum_close",
        "error_feedback_residual_small",
    ],
)
def test_distributed(results, key):
    assert results[key] is True, (key, results)
