"""two-tower × SOGAIC integration: the paper's index serving the assigned
retrieval architecture (DESIGN.md §5 'Direct' applicability)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.search import recall_at_k
from repro.models.recsys import (
    build_retrieval_index,
    init_recsys_params,
    item_tower_embed,
    retrieval_scores,
    two_tower_embed,
)


def test_sogaic_index_over_item_tower():
    cfg = get_config("two-tower-retrieval").reduced()
    params = init_recsys_params(jax.random.PRNGKey(0), cfg)
    n_items = cfg.n_items

    # ANN index over the candidate tower (the paper's system in situ)
    index, report = build_retrieval_index(params, cfg, n_items=n_items)
    assert report.graph["n_components"] == 1

    # queries = user-tower embeddings
    rng = np.random.default_rng(0)
    offs = np.concatenate([[0], np.cumsum(cfg.vocab_sizes)[:-1]])
    sparse = jnp.asarray(
        (rng.integers(0, 20, (16, cfg.n_sparse)) + offs[: cfg.n_sparse]).astype(np.int32)
    )
    dense = jnp.asarray(rng.normal(size=(16, cfg.n_dense)).astype(np.float32))
    q = np.asarray(two_tower_embed(params, cfg, sparse, dense))

    # brute-force ground truth (max inner product == min L2 on normalized)
    cand = item_tower_embed(params, jnp.arange(n_items))
    _, gt = retrieval_scores(jnp.asarray(q), cand, k=10)

    ids, _ = index.search(q, 10, beam_l=64)
    r = recall_at_k(ids, np.asarray(gt))
    assert r >= 0.85, f"ANN retrieval recall {r}"
