"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Per the deliverable: shape/dtype sweeps + hypothesis property tests
asserting allclose against ref.py.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _arr(shape, dtype=np.float32, scale=1.0, seed=None):
    rng = np.random.default_rng(seed) if seed is not None else RNG
    return jnp.asarray((rng.normal(size=shape) * scale).astype(dtype))


@pytest.mark.parametrize("m,n,d", [(8, 16, 4), (100, 257, 96), (256, 512, 128), (33, 1000, 100)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_pairwise_l2_sweep(m, n, d, dtype):
    q = _arr((m, d), np.float32).astype(dtype)
    db = _arr((n, d), np.float32).astype(dtype)
    got = ops.pairwise_l2(q, db, impl="interpret")
    want = ref.pairwise_l2_ref(q, db)
    tol = 1e-4 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("m,n,d,k", [(16, 64, 8, 4), (100, 1000, 96, 16), (64, 300, 32, 32)])
def test_l2_topk_sweep(m, n, d, k):
    q = _arr((m, d))
    db = _arr((n, d))
    gd, gi = ops.l2_topk(q, db, k, impl="interpret")
    wd, wi = ref.l2_topk_ref(q, db, k)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(wd), rtol=1e-4, atol=1e-4)
    # idx must match except where distances tie (random floats: no ties)
    assert (np.asarray(gi) == np.asarray(wi)).mean() > 0.999


def test_l2_topk_ascending_and_valid():
    q = _arr((32, 16))
    db = _arr((200, 16))
    gd, gi = ops.l2_topk(q, db, 8, impl="interpret")
    gd = np.asarray(gd)
    gi = np.asarray(gi)
    assert (np.diff(gd, axis=1) >= -1e-6).all(), "ascending distances"
    assert ((gi >= 0) & (gi < 200)).all()


@pytest.mark.parametrize("n,m,k,dsub", [(64, 4, 16, 8), (100, 8, 256, 12), (512, 16, 256, 8)])
def test_pq_encode_sweep(n, m, k, dsub):
    x = _arr((n, m * dsub))
    cb = _arr((m, k, dsub))
    got = ops.pq_encode_codes(x, cb, impl="interpret")
    want = ref.pq_encode_ref(x, cb)
    assert (np.asarray(got) == np.asarray(want)).mean() > 0.999


@hypothesis.given(
    m=st.integers(1, 64),
    n=st.integers(2, 300),
    d=st.integers(1, 160),
    seed=st.integers(0, 2**16),
)
@hypothesis.settings(max_examples=20, deadline=None)
def test_property_pairwise_l2(m, n, d, seed):
    q = _arr((m, d), seed=seed)
    db = _arr((n, d), seed=seed + 1)
    got = ops.pairwise_l2(q, db, impl="interpret")
    want = ref.pairwise_l2_ref(q, db)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-3)


@hypothesis.given(
    m=st.integers(1, 48),
    n=st.integers(8, 200),
    d=st.integers(2, 64),
    k=st.integers(1, 8),
    seed=st.integers(0, 2**16),
)
@hypothesis.settings(max_examples=20, deadline=None)
def test_property_l2_topk(m, n, d, k, seed):
    k = min(k, n)
    q = _arr((m, d), seed=seed)
    db = _arr((n, d), seed=seed + 1)
    gd, gi = ops.l2_topk(q, db, k, impl="interpret")
    wd, wi = ref.l2_topk_ref(q, db, k)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(wd), rtol=1e-4, atol=1e-3)


def test_oracle_consistency_with_core():
    """kernels.ref and core.kmeans compute the same distances."""
    from repro.core.kmeans import pairwise_sq_l2

    q = _arr((20, 12))
    db = _arr((30, 12))
    np.testing.assert_allclose(
        np.asarray(pairwise_sq_l2(q, db)), np.asarray(ref.pairwise_l2_ref(q, db)),
        rtol=1e-6,
    )
