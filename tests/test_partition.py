"""Algorithm 1 — oracle parity + invariants (unit + hypothesis property)."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kmeans import kmeans_fit
from repro.core.partition import (
    PartitionConfig,
    assign_chunk,
    assign_reference,
    estimate_num_partitions,
    partition_all,
)


def _centroids(x, phi, seed=1):
    return np.asarray(
        kmeans_fit(jax.random.PRNGKey(seed), jnp.asarray(x), phi).centroids
    )


def test_phi_formula():
    assert estimate_num_partitions(10_000, 1000, 4) == 40
    assert estimate_num_partitions(1, 1000, 4) == 1
    assert estimate_num_partitions(1000, 999, 2) == 3


def test_reference_matches_figure_semantics():
    """Figure 1(a): P assigned to nearest; 2nd nearest iff d2 ≤ ε·d1."""
    c = np.array([[0.0, 0.0], [1.0, 0.0], [10.0, 0.0]], np.float32)
    x = np.array([[0.4, 0.0]], np.float32)  # d = [0.4, 0.6, 9.6]
    a, sizes = assign_reference(x, c, omega=3, eps=1.6, gamma=10)
    # avg after first = 0.4; 0.6 <= 1.6*0.4 → accept; avg=0.5; 9.6 > 0.8 → stop
    assert a[0] == [0, 1]
    a2, _ = assign_reference(x, c, omega=3, eps=1.4, gamma=10)
    # 0.6 > 1.4*0.4=0.56 → only nearest
    assert a2[0] == [0]


def test_reference_overload_reset():
    """Line 17: when the nearest set is full the walk resets the average."""
    c = np.array([[0.0, 0.0], [1.0, 0.0], [2.0, 0.0]], np.float32)
    x = np.array([[0.1, 0.0], [0.05, 0.0]], np.float32)
    a, sizes = assign_reference(x, c, omega=1, eps=1.01, gamma=1)
    # vector 0 fills set 0; vector 1 must land somewhere else (reset → set 1)
    assert a[0] == [0]
    assert a[1] == [1]
    assert sizes.max() <= 1


def test_batched_matches_reference_when_uncontended():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(500, 8)).astype(np.float32)
    cent = _centroids(x, 12)
    ref, ref_sizes = assign_reference(x, cent, omega=3, eps=1.5, gamma=500)
    res = partition_all(
        x, cent, PartitionConfig(gamma=500, omega=3, eps=1.5, chunk_size=128)
    )
    # no capacity pressure → chunked result must equal the oracle exactly
    for i, lst in enumerate(ref):
        got = sorted(res.assign_idx[i][res.assign_idx[i] >= 0].tolist())
        assert got == sorted(lst)
    np.testing.assert_array_equal(res.sizes, ref_sizes)


@pytest.mark.parametrize("skew", [0.0, 1.5])
@pytest.mark.parametrize("eps", [1.2, 1.8])
def test_invariants_under_pressure(skew, eps):
    rng = np.random.default_rng(3)
    n = 1200
    x = rng.normal(size=(n, 8)).astype(np.float32)
    if skew:
        x[: int(n * 0.8)] *= 0.02  # dense ball forces overload
    gamma, omega = 100, 3
    phi = estimate_num_partitions(n, gamma, omega)
    cent = _centroids(x, phi)
    res = partition_all(
        x, cent, PartitionConfig(gamma=gamma, omega=omega, eps=eps, chunk_size=256)
    )
    counts = (res.assign_idx >= 0).sum(1)
    assert (counts >= 1).all(), "every vector lands somewhere"
    assert (counts <= omega).all(), "Ω bound"
    assert res.sizes.max() <= gamma, "Γ bound (overload-aware)"
    assert res.sizes.sum() == counts.sum()
    # adaptive overlap stays below the fixed-Ω baseline
    assert res.avg_overlap <= omega


def test_assign_chunk_valid_mask():
    rng = np.random.default_rng(5)
    x = rng.normal(size=(64, 4)).astype(np.float32)
    cent = rng.normal(size=(8, 4)).astype(np.float32)
    valid = np.zeros(64, bool)
    valid[:40] = True
    res = assign_chunk(
        jnp.asarray(x), jnp.asarray(cent), jnp.zeros(8, jnp.int32),
        jnp.asarray(valid), omega=2, eps=1.5, gamma=1000,
    )
    accept = np.asarray(res.accept)
    assert accept[40:].sum() == 0, "padding rows must not claim capacity"
    assert int(np.asarray(res.added).sum()) == accept[:40].sum()


@hypothesis.given(
    n=st.integers(50, 300),
    d=st.integers(2, 12),
    omega=st.integers(2, 5),
    eps=st.floats(1.05, 3.0),
    seed=st.integers(0, 2**16),
)
@hypothesis.settings(max_examples=15, deadline=None)
def test_property_capacity_and_coverage(n, d, omega, eps, seed):
    """Property: for any data/params, Γ is never exceeded and every vector
    is assigned to between 1 and Ω subsets."""
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=(n, d)) * rng.uniform(0.02, 2.0)).astype(np.float32)
    gamma = max(10, n // rng.integers(2, 8))
    phi = estimate_num_partitions(n, gamma, omega)
    cent = x[rng.choice(n, size=phi, replace=False)] + rng.normal(
        size=(phi, d)
    ).astype(np.float32) * 0.01
    res = partition_all(
        x, cent.astype(np.float32),
        PartitionConfig(gamma=gamma, omega=omega, eps=float(eps), chunk_size=64),
    )
    counts = (res.assign_idx >= 0).sum(1)
    assert res.sizes.max() <= gamma
    assert (counts >= 1).all() and (counts <= omega).all()


@hypothesis.given(
    n=st.integers(20, 120),
    omega=st.integers(2, 4),
    eps=st.floats(1.1, 2.5),
    seed=st.integers(0, 2**16),
)
@hypothesis.settings(max_examples=15, deadline=None)
def test_property_walk_prefix_monotone(n, omega, eps, seed):
    """Property (sequential oracle): accepted distances are non-decreasing
    and the ε test holds at each acceptance step."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 6)).astype(np.float32)
    phi = max(omega + 1, n // 10)
    cent = rng.normal(size=(phi, 6)).astype(np.float32)
    assigns, _ = assign_reference(x, cent, omega=omega, eps=float(eps), gamma=n)
    for v, lst in enumerate(assigns):
        d = np.sqrt(((x[v][None] - cent) ** 2).sum(-1))
        dists = [d[i] for i in lst]
        assert all(dists[i] <= dists[i + 1] + 1e-6 for i in range(len(dists) - 1))
        acc = 0.0
        for t, dist in enumerate(dists):
            if t == 0:
                acc = dist
                continue
            avg = acc / t
            assert dist <= eps * avg + 1e-5
            acc += dist
