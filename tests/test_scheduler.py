"""LPT scheduling, dynamic executor: failures, stragglers, elasticity."""

import hypothesis
import hypothesis.strategies as st
import numpy as np
import pytest

from repro.core.scheduler import (
    ClusterScheduler,
    ScheduledTask,
    fit_linear_cost,
    lpt_schedule,
    makespan_lower_bound,
)
from repro.distributed.cluster_sim import SimulatedCluster


def test_lpt_basic():
    costs = [7, 5, 4, 3, 2, 2]
    assignment, makespan = lpt_schedule(costs, 3)
    assert sorted(t for a in assignment for t in a) == list(range(6))
    # LPT gives 9 on this instance (optimum is 8) — within the 4/3 bound
    assert makespan == 9


@hypothesis.given(
    costs=st.lists(st.floats(0.1, 100.0), min_size=1, max_size=60),
    m=st.integers(1, 8),
)
@hypothesis.settings(max_examples=40, deadline=None)
def test_property_lpt_bound(costs, m):
    """LPT ≤ (4/3 − 1/(3m))·OPT; OPT ≥ max(mean load, max cost)."""
    _, makespan = lpt_schedule(costs, m)
    lb = makespan_lower_bound(costs, m)
    assert makespan <= (4 / 3 - 1 / (3 * m)) * lb + max(costs) + 1e-6
    assert makespan >= lb - 1e-6


def test_cluster_completes_all():
    sched = ClusterScheduler(4)
    tasks = [ScheduledTask(i, cost=float(i % 5 + 1)) for i in range(20)]
    done = []
    res = sched.run(
        tasks, lambda t, w: t.cost, on_complete=lambda t, w, c: done.append(t.task_id)
    )
    assert res["n_completed"] == 20
    assert sorted(t for t in done if t >= 0) == list(range(20))


def test_failed_workers_retry():
    cluster = SimulatedCluster(4, fail_prob=0.3, max_failures=3, seed=1)
    sched = ClusterScheduler(4, max_attempts=8)
    tasks = [ScheduledTask(i, cost=1.0) for i in range(12)]
    res = sched.run(tasks, cluster.cost_runner())
    assert res["n_completed"] == 12
    fails = [e for e in sched.log if e["ev"] == "worker_failed"]
    assert len(fails) == 3, "simulator injected exactly max_failures deaths"


def test_straggler_speculation():
    cluster = SimulatedCluster(
        4, straggler_prob=0.4, straggler_slowdown=50.0, seed=3
    )
    sched = ClusterScheduler(4, straggler_factor=2.0)
    tasks = [ScheduledTask(i, cost=1.0) for i in range(8)]
    res = sched.run(tasks, cluster.cost_runner())
    assert res["n_completed"] == 8
    spec = [e for e in sched.log if e["ev"] == "speculate"]
    assert spec, "stragglers must trigger speculative duplicates"
    # speculation must beat waiting for the 50× straggler
    assert res["makespan"] < 50.0


def test_elastic_add_worker():
    sched = ClusterScheduler(1)
    sched.add_worker(speed=2.0)
    tasks = [ScheduledTask(i, cost=1.0) for i in range(8)]
    res = sched.run(tasks, lambda t, w: t.cost)
    loads = res["per_worker_load"]
    assert set(loads) == {0, 1}, "new worker must receive work"
    assert res["makespan"] < 8.0


def test_remove_worker_mid_stream():
    sched = ClusterScheduler(3)
    removed = []

    def on_complete(task, wid, clock):
        if len(removed) == 0:
            sched.remove_worker(2)
            removed.append(2)

    tasks = [ScheduledTask(i, cost=1.0) for i in range(10)]
    res = sched.run(tasks, lambda t, w: t.cost, on_complete=on_complete)
    assert res["n_completed"] == 10


def test_priority_order():
    """Higher-priority (higher-overlap merge) tasks launch first."""
    sched = ClusterScheduler(1, speculation=False)
    order = []
    tasks = [
        ScheduledTask(0, cost=1.0, priority=0.0),
        ScheduledTask(1, cost=1.0, priority=9.0),
        ScheduledTask(2, cost=1.0, priority=5.0),
    ]
    sched.run(tasks, lambda t, w: (order.append(t.task_id), t.cost)[1])
    assert order == [1, 2, 0]


def test_fit_linear_cost():
    sizes = np.array([100, 200, 400, 800])
    times = 0.5 + 0.01 * sizes
    c0, c1 = fit_linear_cost(sizes, times)
    assert abs(c0 - 0.5) < 1e-6 and abs(c1 - 0.01) < 1e-9
