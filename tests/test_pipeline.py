"""End-to-end SOGAIC build: recall, checkpoint resume, fault injection."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.core.pipeline import SOGAICBuilder, SOGAICConfig
from repro.core.search import brute_force_topk, recall_at_k
from repro.distributed.cluster_sim import SimulatedCluster


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(3000, 16)).astype(np.float32)
    q = rng.normal(size=(40, 16)).astype(np.float32)
    _, gt = brute_force_topk(jnp.asarray(x), jnp.asarray(q), 10)
    return x, q, np.asarray(gt)


CFG = SOGAICConfig(
    gamma=700, omega=3, eps=1.6, chunk_size=1024, r=20, n_workers=4,
    sample_size=1500, kmeans_iters=12,
)


def test_build_and_search(data, tmp_path):
    x, q, gt = data
    ckpt = CheckpointManager(str(tmp_path / "ck"))
    idx, rep = SOGAICBuilder(CFG).build(x, ckpt=ckpt)
    assert rep.phi == -(-3 * 3000 // 700)
    assert rep.graph["n_components"] == 1
    assert rep.avg_overlap <= CFG.omega
    ids, _ = idx.search(q, 10, beam_l=64)
    r = recall_at_k(ids, gt)
    assert r >= 0.9, f"recall {r}"

    # resume: all stages checkpointed → near-instant, same graph
    idx2, rep2 = SOGAICBuilder(CFG).build(x, ckpt=ckpt)
    np.testing.assert_array_equal(idx.adj, idx2.adj)
    assert sum(rep2.timings.values()) < sum(rep.timings.values()) / 2

    # index round-trip through the checkpoint
    from repro.core.pipeline import SOGAICIndex

    idx3 = SOGAICIndex.load(ckpt)
    ids3, _ = idx3.search(q, 10, beam_l=64)
    assert recall_at_k(ids3, gt) >= 0.9


def test_build_with_failures_and_stragglers(data):
    """Fault-injected cluster: the build must complete with full quality
    despite worker deaths mid-task and 4× stragglers (retries + speculative
    duplicates handle both)."""
    x, q, gt = data
    cluster = SimulatedCluster(
        4, fail_prob=0.2, max_failures=4, straggler_prob=0.2,
        straggler_slowdown=4.0, seed=7,
    )
    idx, rep = SOGAICBuilder(CFG).build(x, runner_wrapper=cluster.wrap)
    assert rep.graph["n_components"] == 1
    ids, _ = idx.search(q, 10, beam_l=64)
    assert recall_at_k(ids, gt) >= 0.9
    assert cluster._failures >= 1, "the simulator must have injected failures"


def test_build_single_partition():
    """N ≤ Γ → one subset, no merge stage."""
    rng = np.random.default_rng(5)
    x = rng.normal(size=(400, 8)).astype(np.float32)
    cfg = SOGAICConfig(gamma=1600, omega=2, eps=1.5, chunk_size=256, r=12,
                       sample_size=400, n_workers=2)
    idx, rep = SOGAICBuilder(cfg).build(x)
    assert rep.phi == 1
    assert rep.merge_makespan == 0.0
    assert idx.adj.shape == (400, 12)


def test_pq_fused_encoding(data, tmp_path):
    x, q, gt = data
    import dataclasses

    cfg = dataclasses.replace(CFG, pq_m=4)
    ckpt = CheckpointManager(str(tmp_path / "pq"))
    idx, rep = SOGAICBuilder(cfg).build(x, ckpt=ckpt)
    assert idx.pq_codes is not None and idx.pq_codes.shape == (3000, 4)
    # codes must match a direct (non-fused) encode — encoded exactly once
    from repro.core.pq import pq_encode

    codes = np.asarray(pq_encode(jnp.asarray(x, jnp.float32), idx.pq_codebook))
    np.testing.assert_array_equal(idx.pq_codes, codes)


def test_centroid_routed_entries_on_clustered_data():
    """The beyond-paper serving fix: single-medoid entry collapses on
    cluster-structured data; centroid-routed entries recover recall
    (EXPERIMENTS.md §Paper-reproduction, isd3b)."""
    from repro.data.datasets import DATASETS
    from repro.core.search import beam_search
    from repro.core.graph import find_medoid

    spec = DATASETS["isd3b"]
    n = 3000
    x = spec.generate(n + 50, seed=2)
    x, q = x[:n], x[n : n + 50]
    gt = np.asarray(brute_force_topk(jnp.asarray(x), jnp.asarray(q), 10)[1])
    cfg = SOGAICConfig(gamma=n // 6, omega=4, eps=1.8, chunk_size=1024, r=20,
                       n_workers=4, sample_size=n, kmeans_iters=12)
    idx, rep = SOGAICBuilder(cfg).build(x)
    routed_ids, _ = idx.search(q, 10, beam_l=64)
    r_routed = recall_at_k(routed_ids, gt)
    # medoid-only search on the same graph
    res = beam_search(
        jnp.asarray(x, jnp.float32), jnp.asarray(idx.adj), jnp.asarray(q),
        find_medoid(jnp.asarray(x, jnp.float32)), k=10, beam_l=64, max_hops=96,
    )
    r_medoid = recall_at_k(np.asarray(res.ids), gt)
    assert r_routed >= r_medoid, (r_routed, r_medoid)
    assert r_routed >= 0.5, f"routed recall {r_routed}"
