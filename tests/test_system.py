"""End-to-end behaviour tests for the SOGAIC system.

The detailed suites live in the sibling test modules; this file keeps the
top-level story: built index answers queries at high recall, survives a
hostile cluster, resumes from checkpoints, and the dry-run machinery can
lower a small cell.
"""

import numpy as np
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.core.pipeline import SOGAICBuilder, SOGAICConfig
from repro.core.search import brute_force_topk, recall_at_k
from repro.distributed.cluster_sim import SimulatedCluster


def test_end_to_end_story(tmp_path):
    rng = np.random.default_rng(42)
    x = rng.normal(size=(2500, 20)).astype(np.float32)
    q = rng.normal(size=(30, 20)).astype(np.float32)
    gt = np.asarray(brute_force_topk(jnp.asarray(x), jnp.asarray(q), 10)[1])

    cfg = SOGAICConfig(
        gamma=600, omega=3, eps=1.8, chunk_size=1024, r=20,
        n_workers=4, sample_size=1200, kmeans_iters=10,
    )
    cluster = SimulatedCluster(4, fail_prob=0.15, max_failures=2,
                               straggler_prob=0.15, seed=11)
    ckpt = CheckpointManager(str(tmp_path))
    index, report = SOGAICBuilder(cfg).build(
        x, ckpt=ckpt, runner_wrapper=cluster.wrap
    )

    # the paper's invariants: bounded subsets, adaptive overlap < Ω,
    # one connected graph, high recall
    assert report.phi == -(-3 * 2500 // 600)
    assert report.avg_overlap < cfg.omega
    assert report.graph["n_components"] == 1
    ids, _ = index.search(q, 10, beam_l=64)
    assert recall_at_k(ids, gt) >= 0.9

    # restart from checkpoint reproduces the index bit-exactly
    index2, report2 = SOGAICBuilder(cfg).build(x, ckpt=ckpt)
    np.testing.assert_array_equal(index.adj, index2.adj)
