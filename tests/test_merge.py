"""Agglomerative merge: invariants + schedule properties."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.graph import build_subgraph, graph_stats
from repro.core.merge import SubGraph, agglomerative_schedule, merge_pair, overlap_counts


def _make_sub(x, ids, r=12):
    sub = jnp.asarray(x[ids], jnp.float32)
    adj = np.asarray(build_subgraph(sub, r))
    return SubGraph(ids=np.asarray(ids, np.int64), adj=adj)


def test_merge_pair_invariants():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(600, 12)).astype(np.float32)
    ids_a = np.sort(rng.choice(600, 350, replace=False))
    ids_b = np.sort(rng.choice(600, 350, replace=False))
    ga, gb = _make_sub(x, ids_a), _make_sub(x, ids_b)
    g = merge_pair(ga, gb, x)
    # node set = union
    np.testing.assert_array_equal(g.ids, np.union1d(ids_a, ids_b))
    # degree bound + valid local indices
    assert g.adj.shape[1] == max(ga.r, gb.r)
    assert g.adj.max() < g.n and g.adj.min() >= -1
    # disjoint-part rows carried over: a node only in A keeps its A neighbors
    only_a = np.setdiff1d(ids_a, ids_b)
    pos = {int(v): i for i, v in enumerate(g.ids)}
    pos_a = {int(v): i for i, v in enumerate(ga.ids)}
    overlap = set(np.intersect1d(ids_a, ids_b).tolist())
    checked = 0
    for v in only_a[:50]:
        row_a = set(
            int(ga.ids[j]) for j in ga.adj[pos_a[int(v)]] if j >= 0
        )
        row_m = set(int(g.ids[j]) for j in g.adj[pos[int(v)]] if j >= 0)
        # carried over unless a backlink stitched an overlap node in
        if not (row_m - row_a):
            assert row_a == row_m or row_a >= row_m
            checked += 1
    assert checked > 0


def test_merge_connectivity_improves():
    """Merging two halves of a dataset yields one connected graph."""
    rng = np.random.default_rng(1)
    x = rng.normal(size=(500, 10)).astype(np.float32)
    # overlapping halves (with shared middle band → bridge nodes)
    ids_a = np.arange(0, 320)
    ids_b = np.arange(180, 500)
    g = merge_pair(_make_sub(x, ids_a), _make_sub(x, ids_b), x)
    assert g.n == 500
    stats = graph_stats(g.adj)
    assert stats["n_components"] == 1


def test_overlap_counts():
    members = [np.array([0, 1, 2, 3]), np.array([2, 3, 4]), np.array([9])]
    ov = overlap_counts(members)
    assert ov[0, 1] == 2 and ov[0, 2] == 0 and ov[1, 2] == 0
    assert (ov == ov.T).all()


def test_agglomerative_schedule_shape():
    sizes = np.array([100, 90, 80, 70, 60])
    ov = np.zeros((5, 5), np.int64)
    ov[0, 1] = ov[1, 0] = 50  # these two should merge first
    rounds = agglomerative_schedule(sizes, ov)
    # 5 leaves → 4 merges total, ⌈log2⌉ rounds ≥ 3
    assert sum(len(r) for r in rounds) == 4
    assert rounds[0][0] == (0, 1), "highest-overlap pair first"
    # every node consumed exactly once
    used = [n for r in rounds for p in r for n in p]
    assert len(used) == len(set(used))


def test_schedule_single():
    assert agglomerative_schedule(np.array([10]), np.zeros((1, 1))) == []
