"""Pallas TPU kernel: fused product-quantization encoding.

Runs inside the partition chunk pipeline (paper Fig. 1c — PQ encoding
parallel with vector assignment, each vector encoded exactly once).  For a
chunk of vectors the kernel computes, per subspace, the distances to all
K codewords and the argmin — one (bb, dsub)×(dsub, K) MXU matmul plus a
VPU argmin per (block, subspace) grid cell, with codes written straight
back as int32 (cast to uint8 at the ops layer).

Grid (B/bb, M): x viewed as (B, M, dsub), codebooks (M, K, dsub).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pq_encode_kernel", "pq_encode_pallas"]


def pq_encode_kernel(x_ref, cb_ref, out_ref):
    """x (bb, 1, dsub); cb (1, K, dsub); out (bb, 1) int32."""
    xb = x_ref[...][:, 0, :].astype(jnp.float32)  # (bb, dsub)
    cb = cb_ref[...][0].astype(jnp.float32)  # (K, dsub)
    x2 = jnp.sum(xb * xb, axis=1, keepdims=True)
    c2 = jnp.sum(cb * cb, axis=1, keepdims=True).T
    xc = jax.lax.dot_general(
        xb, cb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    d2 = x2 + c2 - 2.0 * xc  # (bb, K)
    out_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)[:, None]


@functools.partial(jax.jit, static_argnames=("bb", "interpret"))
def pq_encode_pallas(
    x: jax.Array,
    codebooks: jax.Array,
    *,
    bb: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """PQ codes (n, M) int32; n must tile by bb (ops.py pads)."""
    n, d = x.shape
    m, k, dsub = codebooks.shape
    assert d == m * dsub, (d, m, dsub)
    assert n % bb == 0, (n, bb)
    x3 = x.reshape(n, m, dsub)
    grid = (n // bb, m)
    return pl.pallas_call(
        pq_encode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, 1, dsub), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, k, dsub), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.int32),
        interpret=interpret,
    )(x3, codebooks)
