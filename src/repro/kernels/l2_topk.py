"""Pallas TPU kernel: fused pairwise-L2 + running top-k.

The memory-roofline win for index construction.  The naive pipeline
materializes the full (M, N) distance tile in HBM and then runs ``top_k``
— O(M·N) HBM bytes.  This kernel keeps a (bm, k) running top-k in VMEM
while streaming db blocks, so HBM traffic drops to O(M·k + M·D + N·D):
for Γ-sized subsets (N ~ 10⁵–10⁶) that is a ~N/k ≈ 10³× reduction in
distance-matrix bytes, which converts the kNN stage from memory-bound to
MXU-bound (§Perf in EXPERIMENTS.md quantifies this on the dry-run).

Layout: grid (M/bm, N/bn) with the db axis minor/sequential.  Queries and
db blocks carry the full feature dim (embedding dims here are ≤ 1k — they
fit VMEM).  The merge step is a fixed-k selection loop: k iterations of
(argmin → record → mask), entirely VPU ops on a (bm, k+bn) VMEM tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["l2_topk_kernel", "l2_topk_pallas"]


def _selection_merge(d_run, i_run, d_new, i_new, k):
    """Merge running (bm, k) top-k with candidate (bm, bn) block.

    k-step selection: repeatedly take the row-wise min of the concatenated
    tile, record it, mask it out.  Returns new (d_run, i_run).
    """
    cat_d = jnp.concatenate([d_run, d_new], axis=1)  # (bm, k+bn)
    cat_i = jnp.concatenate([i_run, i_new], axis=1)
    bm = cat_d.shape[0]
    rows = jnp.arange(bm)

    def body(t, carry):
        cat_d, cat_i, out_d, out_i = carry
        col = jnp.argmin(cat_d, axis=1)  # (bm,)
        best_d = cat_d[rows, col]
        best_i = cat_i[rows, col]
        out_d = jax.lax.dynamic_update_slice(out_d, best_d[:, None], (0, t))
        out_i = jax.lax.dynamic_update_slice(out_i, best_i[:, None], (0, t))
        cat_d = cat_d.at[rows, col].set(jnp.inf)
        return cat_d, cat_i, out_d, out_i

    out_d = jnp.full((bm, k), jnp.inf, jnp.float32)
    out_i = jnp.full((bm, k), -1, jnp.int32)
    _, _, out_d, out_i = jax.lax.fori_loop(0, k, body, (cat_d, cat_i, out_d, out_i))
    return out_d, out_i


def l2_topk_kernel(q_ref, db_ref, dist_ref, idx_ref, *, k: int, bn: int):
    """Grid (i, j): q (bm, d), db (bn, d); outputs (bm, k) revisited over j."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        dist_ref[...] = jnp.full_like(dist_ref, jnp.inf)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    qb = q_ref[...].astype(jnp.float32)
    db = db_ref[...].astype(jnp.float32)
    q2 = jnp.sum(qb * qb, axis=1, keepdims=True)
    c2 = jnp.sum(db * db, axis=1, keepdims=True).T
    qc = jax.lax.dot_general(
        qb, db, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    d_new = jnp.maximum(q2 + c2 - 2.0 * qc, 0.0)  # (bm, bn)
    i_new = (j * bn + jax.lax.broadcasted_iota(jnp.int32, d_new.shape, 1))

    d_run, i_run = _selection_merge(dist_ref[...], idx_ref[...], d_new, i_new, k)
    dist_ref[...] = d_run
    idx_ref[...] = i_run


@functools.partial(jax.jit, static_argnames=("k", "bm", "bn", "interpret"))
def l2_topk_pallas(
    q: jax.Array,
    db: jax.Array,
    k: int,
    *,
    bm: int = 256,
    bn: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused distance+top-k: (sq_dists (m, k) ascending, idx (m, k) int32)."""
    m, d = q.shape
    n, d2 = db.shape
    assert d == d2
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    assert k <= bn, "running top-k must fit one db block"
    grid = (m // bm, n // bn)
    dists, idx = pl.pallas_call(
        functools.partial(l2_topk_kernel, k=k, bn=bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.float32),
            jax.ShapeDtypeStruct((m, k), jnp.int32),
        ],
        interpret=interpret,
    )(q, db)
    return dists, idx
