"""Jitted public wrappers around the Pallas kernels.

Dispatch policy (``impl=``):
  "auto"    — Pallas compiled on TPU, jnp oracle elsewhere (CPU/GPU)
  "pallas"  — Pallas compiled (TPU only)
  "interpret" — Pallas in interpreter mode (CPU correctness testing)
  "jnp"     — the pure-jnp oracle from ref.py

Wrappers own all shape legalization: inputs are padded to tile multiples
with sentinels chosen so padding can never contaminate results (∞-distance
rows for top-k, zero rows for plain distances), and outputs are sliced
back.  Core code (repro.core.*) calls these, never the kernels directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.l2_topk import l2_topk_pallas
from repro.kernels.pairwise_l2 import pairwise_l2_pallas
from repro.kernels.pq_encode import pq_encode_pallas

__all__ = ["pairwise_l2", "l2_topk", "pq_encode_codes", "default_impl"]


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def _pad_rows(a: jax.Array, target: int, value: float = 0.0) -> jax.Array:
    if a.shape[0] == target:
        return a
    return jnp.pad(a, ((0, target - a.shape[0]), (0, 0)), constant_values=value)


def pairwise_l2(
    q: jax.Array,
    db: jax.Array,
    *,
    impl: str = "auto",
    bm: int = 256,
    bn: int = 256,
    bk: int = 128,
) -> jax.Array:
    """Squared L2 distance matrix (m, n), f32."""
    impl = default_impl() if impl == "auto" else impl
    if impl == "jnp":
        return ref.pairwise_l2_ref(q, db)
    m, d = q.shape
    n, _ = db.shape
    bm = min(bm, _round_up(m, 8))
    bn = min(bn, _round_up(n, 128))
    bk = min(bk, _round_up(d, 128))
    mp, np_, dp = _round_up(m, bm), _round_up(n, bn), _round_up(d, bk)
    qp = jnp.pad(q, ((0, mp - m), (0, dp - d)))
    dbp = jnp.pad(db, ((0, np_ - n), (0, dp - d)))
    out = pairwise_l2_pallas(
        qp, dbp, bm=bm, bn=bn, bk=bk, interpret=(impl == "interpret")
    )
    return out[:m, :n]


def l2_topk(
    q: jax.Array,
    db: jax.Array,
    k: int,
    *,
    impl: str = "auto",
    bm: int = 256,
    bn: int = 512,
) -> tuple[jax.Array, jax.Array]:
    """Fused k-nearest: (sq_dists (m, k) ascending, idx (m, k) int32).

    Padding db rows sit at +∞ distance (sentinel coordinates are never
    materialized — the kernel masks via index range), padding query rows
    are discarded on slice-out.
    """
    impl = default_impl() if impl == "auto" else impl
    if impl == "jnp":
        return ref.l2_topk_ref(q, db, k)
    m, d = q.shape
    n, _ = db.shape
    bm = min(bm, _round_up(m, 8))
    bn = min(bn, _round_up(n, 128))
    bn = max(bn, _round_up(k, 128))  # running top-k must fit a db block
    mp, np_ = _round_up(m, bm), _round_up(n, bn)
    qp = _pad_rows(q, mp)
    # db pads: replicate the norm structure but push distance to +inf by
    # masking in-kernel is avoided — instead pad with a huge constant row.
    if np_ > n:
        big = jnp.full((np_ - n, d), 3.4e18, db.dtype if db.dtype == jnp.float32 else jnp.float32)
        dbp = jnp.concatenate([db.astype(big.dtype), big], axis=0)
    else:
        dbp = db
    dists, idx = l2_topk_pallas(
        qp, dbp, k, bm=bm, bn=bn, interpret=(impl == "interpret")
    )
    dists, idx = dists[:m], idx[:m]
    # pads (idx ≥ n) → mark invalid
    bad = idx >= n
    return jnp.where(bad, jnp.inf, dists), jnp.where(bad, -1, idx)


def pq_encode_codes(
    x: jax.Array,
    codebooks: jax.Array,
    *,
    impl: str = "auto",
    bb: int = 512,
) -> jax.Array:
    """PQ codes (n, M) int32."""
    impl = default_impl() if impl == "auto" else impl
    if impl == "jnp":
        return ref.pq_encode_ref(x, codebooks)
    n, d = x.shape
    bb = min(bb, _round_up(n, 8))
    np_ = _round_up(n, bb)
    xp = _pad_rows(x, np_)
    codes = pq_encode_pallas(xp, codebooks, bb=bb, interpret=(impl == "interpret"))
    return codes[:n]
