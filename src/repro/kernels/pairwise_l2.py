"""Pallas TPU kernel: tiled pairwise squared-L2 distance.

The single hottest op in the whole SOGAIC pipeline — K-means seeding,
Algorithm-1 candidate generation, exact-kNN subgraph build, merge re-prune
and PQ training all reduce to ``|q − c|²`` tiles.  Squared L2 decomposes
additively over the feature dimension, so the kernel accumulates per-
k-block partials

    out[i, j] += Σ_d∈blk q[i,d]² + c[j,d]² − 2·q[i,d]·c[j,d]

over a (M/bm, N/bn, D/bk) grid with the contraction as the minor
(sequential) grid axis — the ``−2·q·cᵀ`` term is a (bm, bk)×(bk, bn) MXU
matmul per step and the norm terms are VPU row reductions fused into the
same VMEM-resident tile.  All tile dims default to multiples of 128
(MXU-aligned); f32 accumulation regardless of input dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pairwise_l2_kernel", "pairwise_l2_pallas"]


def pairwise_l2_kernel(q_ref, c_ref, out_ref):
    """Grid (i, j, k); q (bm, bk), c (bn, bk), out (bm, bn) revisited over k."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    qb = q_ref[...].astype(jnp.float32)  # (bm, bk)
    cb = c_ref[...].astype(jnp.float32)  # (bn, bk)
    q2 = jnp.sum(qb * qb, axis=1, keepdims=True)  # (bm, 1)
    c2 = jnp.sum(cb * cb, axis=1, keepdims=True).T  # (1, bn)
    qc = jax.lax.dot_general(
        qb, cb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    out_ref[...] += q2 + c2 - 2.0 * qc


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def pairwise_l2_pallas(
    q: jax.Array,
    db: jax.Array,
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Squared L2 (m, n); shapes must tile evenly (ops.py pads)."""
    m, d = q.shape
    n, d2_ = db.shape
    assert d == d2_, (d, d2_)
    assert m % bm == 0 and n % bn == 0 and d % bk == 0, (m, n, d, bm, bn, bk)
    grid = (m // bm, n // bn, d // bk)
    return pl.pallas_call(
        pairwise_l2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(q, db)
