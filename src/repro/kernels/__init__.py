"""Pallas TPU kernels for SOGAIC's compute hot-spots.

Three kernels, each with an explicit-BlockSpec VMEM tiling, a jitted
wrapper in ``ops.py`` and a pure-jnp oracle in ``ref.py``:

  pairwise_l2  tiled squared-L2 distance (the MXU workhorse everywhere)
  l2_topk      fused distance + running top-k (kNN build, Algorithm-1
               candidate generation) — collapses O(M·N) HBM traffic to
               O(M·k)
  pq_encode    fused per-subspace distance + argmin (PQ encoding in the
               partition chunk pipeline)

On this CPU container the kernels are validated in ``interpret=True``
mode against the oracles; ``ops.py`` dispatches to compiled Pallas on TPU.
"""

from repro.kernels.ops import l2_topk, pairwise_l2, pq_encode_codes

__all__ = ["pairwise_l2", "l2_topk", "pq_encode_codes"]
