"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each kernel's test sweeps shapes and
dtypes and asserts allclose against the functions here (kernels run in
``interpret=True`` on CPU, compiled on TPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["pairwise_l2_ref", "l2_topk_ref", "pq_encode_ref"]


def pairwise_l2_ref(q: jax.Array, db: jax.Array) -> jax.Array:
    """Squared L2 distances (m, n) between rows of q (m, d) and db (n, d)."""
    q = q.astype(jnp.float32)
    db = db.astype(jnp.float32)
    q2 = jnp.sum(q * q, axis=-1, keepdims=True)
    d2 = jnp.sum(db * db, axis=-1)[None, :]
    qd = jax.lax.dot_general(
        q, db, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    return jnp.maximum(q2 - 2.0 * qd + d2, 0.0)


def l2_topk_ref(q: jax.Array, db: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Top-k nearest db rows per query: (sq_dists (m, k) asc, idx (m, k))."""
    d2 = pairwise_l2_ref(q, db)
    neg, idx = jax.lax.top_k(-d2, k)
    return -neg, idx.astype(jnp.int32)


def pq_encode_ref(x: jax.Array, codebooks: jax.Array) -> jax.Array:
    """PQ codes (n, M) int32.

    x (n, M·dsub); codebooks (M, K, dsub).  Per-subspace nearest codeword.
    """
    n = x.shape[0]
    m, k, dsub = codebooks.shape
    xs = x.astype(jnp.float32).reshape(n, m, dsub).transpose(1, 0, 2)  # (M, n, dsub)

    def enc(xsub, cb):
        return jnp.argmin(pairwise_l2_ref(xsub, cb), axis=-1)

    codes = jax.vmap(enc)(xs, codebooks.astype(jnp.float32))  # (M, n)
    return codes.T.astype(jnp.int32)
