"""Distributed runtime: sharded SOGAIC steps, cluster simulation, collectives.

``steps.py`` holds the pjit/shard_map formulations of every pipeline stage
— these are the functions the multi-pod dry-run lowers and compiles, and
the roofline analysis reads.  ``cluster_sim.py`` provides the virtual
cluster (failures, stragglers, elasticity) that exercises the scheduler's
fault-tolerance paths without real hardware.
"""

from repro.distributed.steps import (
    data_axes,
    make_assign_step,
    make_build_step,
    make_knn_step,
    make_merge_step,
    make_pq_encode_step,
)
from repro.distributed.cluster_sim import SimulatedCluster

__all__ = [
    "data_axes",
    "make_assign_step",
    "make_build_step",
    "make_knn_step",
    "make_merge_step",
    "make_pq_encode_step",
    "SimulatedCluster",
]
