"""pjit/shard_map formulations of the SOGAIC pipeline stages.

These are the production device programs.  The mapping (DESIGN.md §4):

  assign     vectors sharded over (pod, data); centroid table sharded over
             ``model`` (each model shard scores its Φ/TP centroids, local
             top-k, all-gather + re-top-k — the TP pattern); capacity
             counters quota-split per data shard and psum'd back
  knn        queries over (pod, data), db rows over ``model`` — local fused
             L2+top-k then all-gather merge (lets Γ exceed device memory)
  build      one subset per device across the *flattened* mesh (the paper's
             "scale by adding low-resource workers"), each device running
             the dense tiled kNN→prune build on its subset
  merge      union-vector table replicated, overlap rows sharded across the
             flattened mesh; optional pod-ring ``ppermute`` models the
             agglomerative exchange of finished subgraphs between pods
  pq_encode  vectors sharded over (pod, data), codebooks replicated

Every factory returns ``(step_fn, in_specs)`` where ``step_fn`` is jitted
and ``in_specs`` are the `PartitionSpec`s the dry-run uses to build sharded
``ShapeDtypeStruct`` inputs.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.graph import build_subgraph, prune_candidate_lists
from repro.core.kmeans import pairwise_sq_l2
from repro.core.partition import _enforce_capacity, _walk

__all__ = [
    "data_axes",
    "flat_axes",
    "make_assign_step",
    "make_knn_step",
    "make_build_step",
    "make_merge_step",
    "make_pq_encode_step",
]


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """The batch-parallel axes: ('pod', 'data') ∩ mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def flat_axes(mesh: Mesh) -> tuple[str, ...]:
    """All mesh axes — used when every device is an independent worker."""
    return tuple(mesh.axis_names)


def _axis_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    s = 1
    for a in axes:
        s *= mesh.shape[a]
    return s


def make_assign_step(
    mesh: Mesh,
    *,
    omega: int,
    gamma: int,
    eps: float,
    k_cand: int = 32,
):
    """Distributed Algorithm-1 chunk step.

    inputs : x (B, d), centroids (Φ, d), sizes (Φ,) int32
    outputs: kept (B, K) bool, cand_idx (B, K) int32, cand_dist (B, K) f32,
             added (Φ,) int32  (already psum'd — the new global counts delta)
    """
    dp = data_axes(mesh)
    n_data = _axis_size(mesh, dp)
    n_model = mesh.shape["model"]

    def body(x_loc, cent_loc, sizes):
        b_loc = x_loc.shape[0]
        phi_loc = cent_loc.shape[0]
        phi = phi_loc * n_model
        k_loc = min(k_cand, phi_loc)
        d2 = pairwise_sq_l2(x_loc, cent_loc)  # (B_loc, Φ_loc) — MXU tile
        neg, idx = jax.lax.top_k(-d2, k_loc)
        mi = jax.lax.axis_index("model")
        idx_g = idx.astype(jnp.int32) + mi.astype(jnp.int32) * phi_loc
        # TP merge: gather each model shard's local top-k, re-top-k.
        gd = jax.lax.all_gather(neg, "model")  # (nm, B_loc, k_loc)
        gi = jax.lax.all_gather(idx_g, "model")
        gd = jnp.transpose(gd, (1, 0, 2)).reshape(b_loc, n_model * k_loc)
        gi = jnp.transpose(gi, (1, 0, 2)).reshape(b_loc, n_model * k_loc)
        kk = min(k_cand, n_model * k_loc)
        neg2, sel = jax.lax.top_k(gd, kk)
        cand_idx = jnp.take_along_axis(gi, sel, axis=1)
        cand_dist = jnp.sqrt(jnp.maximum(-neg2, 0.0))
        # ε-relaxed walk against the global snapshot
        full = sizes[cand_idx] >= gamma
        want = jax.vmap(_walk, in_axes=(0, 0, None, None))(
            cand_dist, full, omega, jnp.float32(eps)
        )
        # per-data-shard capacity quota (chunk-synchronous semantics)
        remaining = jnp.maximum(gamma - sizes, 0).astype(jnp.int32) // n_data
        kept = _enforce_capacity(want, cand_idx, cand_dist, remaining, phi)
        added_loc = jax.ops.segment_sum(
            kept.reshape(-1).astype(jnp.int32),
            cand_idx.reshape(-1),
            num_segments=phi,
        )
        added = jax.lax.psum(added_loc, dp)
        return kept, cand_idx, cand_dist, added

    in_specs = (P(dp, None), P("model", None), P())
    out_specs = (P(dp, None), P(dp, None), P(dp, None), P())
    # outputs are deterministically replicated across 'model' after the
    # all-gather merge; the static vma checker cannot infer that.
    fn = jax.jit(
        jax.shard_map(
            body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    )
    return fn, in_specs


def make_knn_step(mesh: Mesh, *, k: int, score_dtype=jnp.float32):
    """TP exact-kNN: queries over (pod, data); db rows over ``model``.

    inputs : q (B, d), db (N, d)
    outputs: dists (B, k) f32 ascending, idx (B, k) int32 (global rows)

    ``score_dtype=bfloat16`` halves the HBM bytes of the dominant (B, N)
    distance tile (§Perf hillclimb): candidate generation tolerates bf16
    ranking noise because the graph-build re-prunes with exact distances.
    """
    dp = data_axes(mesh)
    n_model = mesh.shape["model"]

    def body(q_loc, db_loc):
        b_loc = q_loc.shape[0]
        n_loc = db_loc.shape[0]
        if score_dtype == jnp.bfloat16:
            qb = q_loc.astype(jnp.bfloat16)
            dbb = db_loc.astype(jnp.bfloat16)
            q2 = jnp.sum(qb.astype(jnp.float32) ** 2, -1, keepdims=True).astype(jnp.bfloat16)
            c2 = jnp.sum(dbb.astype(jnp.float32) ** 2, -1)[None, :].astype(jnp.bfloat16)
            qc = jax.lax.dot_general(
                qb, dbb, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.bfloat16,
            )
            d2 = q2 - 2.0 * qc + c2  # (B_loc, N_loc) bf16 tile
        else:
            d2 = pairwise_sq_l2(q_loc, db_loc)
        kk = min(k, n_loc)
        neg, idx = jax.lax.top_k(-d2, kk)
        neg = neg.astype(jnp.float32)
        mi = jax.lax.axis_index("model")
        idx_g = idx.astype(jnp.int32) + mi.astype(jnp.int32) * n_loc
        gd = jax.lax.all_gather(neg, "model")  # (nm, B_loc, kk)
        gi = jax.lax.all_gather(idx_g, "model")
        gd = jnp.transpose(gd, (1, 0, 2)).reshape(b_loc, n_model * kk)
        gi = jnp.transpose(gi, (1, 0, 2)).reshape(b_loc, n_model * kk)
        neg2, sel = jax.lax.top_k(gd, k)
        return jnp.sqrt(jnp.maximum(-neg2, 0.0)), jnp.take_along_axis(gi, sel, axis=1)

    in_specs = (P(dp, None), P("model", None))
    out_specs = (P(dp, None), P(dp, None))
    fn = jax.jit(
        jax.shard_map(
            body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    )
    return fn, in_specs


def make_build_step(
    mesh: Mesh, *, r: int, alpha: float = 1.2, knn_k: int | None = None
):
    """Per-device subset builds across the flattened mesh.

    inputs : x_sub (S, n, d) — S bucketed subsets; n_valid (S,) int32
    outputs: adj (S, n, R) int32
    """
    fa = flat_axes(mesh)

    def body(x_loc, nv_loc):
        def one(args):
            xs, nv = args
            return build_subgraph(
                xs, r, alpha=alpha, knn_k=knn_k, n_valid=nv,
                block_q=min(512, xs.shape[0]),
            )

        return jax.lax.map(one, (x_loc, nv_loc))

    in_specs = (P(fa, None, None), P(fa))
    out_specs = P(fa, None, None)
    fn = jax.jit(
        jax.shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    )
    return fn, in_specs


def make_merge_step(mesh: Mesh, *, r: int, alpha: float = 1.2):
    """Overlap-region re-prune + pod-ring exchange of finished rows.

    inputs : xu (m, d) replicated union vectors, node_idx (T,), cand (T, C)
    outputs: rows (T, R) int32 — re-pruned adjacency for the overlap nodes
    """
    fa = flat_axes(mesh)
    has_pod = "pod" in mesh.axis_names
    n_pod = mesh.shape["pod"] if has_pod else 1

    def body(xu, node_loc, cand_loc):
        rows = prune_candidate_lists(
            xu, node_loc, cand_loc, r, alpha=alpha, block=min(256, node_loc.shape[0])
        )
        if has_pod and n_pod > 1:
            # agglomerative exchange: ship finished rows to the partner pod
            # for the next merge level (ring permute over the pod axis)
            perm = [(i, (i + 1) % n_pod) for i in range(n_pod)]
            rows = jax.lax.ppermute(rows, "pod", perm)
        return rows

    in_specs = (P(None, None), P(fa), P(fa, None))
    out_specs = P(fa, None)
    fn = jax.jit(
        jax.shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    )
    return fn, in_specs


def make_pq_encode_step(mesh: Mesh):
    """Fused PQ encoding: vectors over (pod, data), codebooks replicated.

    inputs : x (B, d), codebooks (M, K, dsub)
    outputs: codes (B, M) int32
    """
    dp = data_axes(mesh)

    def body(x_loc, codebooks):
        n = x_loc.shape[0]
        m, k, dsub = codebooks.shape
        xs = x_loc.reshape(n, m, dsub).transpose(1, 0, 2)

        def enc(xsub, cb):
            return jnp.argmin(pairwise_sq_l2(xsub, cb), axis=-1)

        codes = jax.vmap(enc)(xs, codebooks)
        return codes.T.astype(jnp.int32)

    in_specs = (P(dp, None), P(None, None, None))
    out_specs = P(dp, None)
    fn = jax.jit(
        jax.shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    )
    return fn, in_specs
