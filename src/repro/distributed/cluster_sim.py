"""Virtual cluster: failure / straggler / heterogeneity injection.

Wraps any task runner with the misbehaviors a 1000+-node fleet exhibits,
so the scheduler's fault-tolerance machinery (retries, speculative
duplicates, elastic re-balance) is exercised deterministically in tests
and benchmarks:

  * ``fail_prob``       — worker dies mid-task (runner returns None;
                          ClusterScheduler re-queues the task)
  * ``straggler_prob``  — task runs ``straggler_slowdown``× long
                          (triggers speculation)
  * ``speed_jitter``    — per-worker heterogeneous throughput
  * ``cost_runner``     — pure simulation mode: durations from the linear
                          cost model instead of real compute (used by the
                          scalability benchmark to sweep worker counts —
                          Fig. 3 right column)
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.scheduler import ScheduledTask

__all__ = ["SimulatedCluster"]


@dataclasses.dataclass
class SimulatedCluster:
    n_workers: int
    fail_prob: float = 0.0
    straggler_prob: float = 0.0
    straggler_slowdown: float = 5.0
    speed_jitter: float = 0.0
    seed: int = 0
    max_failures: int | None = None

    def __post_init__(self) -> None:
        self._rng = np.random.default_rng(self.seed)
        self._speeds = 1.0 + self.speed_jitter * self._rng.standard_normal(
            self.n_workers
        ).clip(-0.9, 3.0)
        self._failures = 0

    def wrap(self, runner: Callable[[ScheduledTask, int], float]) -> Callable:
        """Wrap a real runner: inject failures/stragglers around it."""

        def wrapped(task: ScheduledTask, worker_id: int):
            if self.fail_prob > 0 and self._rng.random() < self.fail_prob:
                if self.max_failures is None or self._failures < self.max_failures:
                    self._failures += 1
                    return None  # worker died; scheduler re-queues
            dur = runner(task, worker_id)
            if dur is None:
                return None
            if self.straggler_prob > 0 and self._rng.random() < self.straggler_prob:
                dur = dur * self.straggler_slowdown
            speed = self._speeds[worker_id % len(self._speeds)]
            return float(dur / max(speed, 0.1))

        return wrapped

    def cost_runner(self, *, noise: float = 0.05) -> Callable:
        """Pure-simulation runner: duration = task.cost (± noise), with
        the same failure/straggler injection — no real compute."""

        def base(task: ScheduledTask, worker_id: int) -> float:
            eps = 1.0 + noise * float(self._rng.standard_normal())
            return float(task.cost) * max(eps, 0.01)

        return self.wrap(base)
