"""Mini-batch / full-batch Lloyd k-means in JAX.

SOGAIC's partitioning stage (paper §2.1) runs K-means on a *small sample* of
the dataset to obtain Φ centroids that seed the overload-aware assignment
walk (Algorithm 1).  Everything here is expressed as MXU-friendly matmuls:
the squared-L2 distance matrix is computed as ``|x|² − 2·x·cᵀ + |c|²`` so the
hot loop is a single GEMM per Lloyd iteration.

The module is self-contained and jit-safe; ``kmeans_fit`` is the public
entry point.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "KMeansState",
    "kmeans_fit",
    "kmeans_plus_plus_init",
    "pairwise_sq_l2",
    "assign_nearest",
]


def pairwise_sq_l2(x: jax.Array, c: jax.Array) -> jax.Array:
    """Squared L2 distances between rows of ``x`` (n, d) and ``c`` (k, d).

    Returned as (n, k), clamped at zero (the expansion can go slightly
    negative in low precision).  The ``x @ c.T`` contraction dominates and
    maps onto the MXU; on TPU the fused Pallas kernel in
    :mod:`repro.kernels` implements the same contraction with explicit VMEM
    tiling — this jnp form is its oracle and the CPU path.
    """
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)  # (n, 1)
    c2 = jnp.sum(c * c, axis=-1)[None, :]  # (1, k)
    xc = jax.lax.dot_general(
        x,
        c,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return jnp.maximum(x2 - 2.0 * xc + c2, 0.0)


def assign_nearest(x: jax.Array, centroids: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Nearest-centroid assignment.  Returns (idx (n,), sq_dist (n,))."""
    d = pairwise_sq_l2(x, centroids)
    idx = jnp.argmin(d, axis=-1)
    return idx, jnp.take_along_axis(d, idx[:, None], axis=-1)[:, 0]


class KMeansState(NamedTuple):
    centroids: jax.Array  # (k, d) float32
    inertia: jax.Array  # () float32 — mean squared distance at last step
    n_iter: jax.Array  # () int32


def kmeans_plus_plus_init(
    key: jax.Array, x: jax.Array, k: int, *, n_local_trials: int = 0
) -> jax.Array:
    """k-means++ seeding (Arthur & Vassilvitskii).

    Sequential over ``k`` picks but each pick is a full-width distance
    update, so the loop body is a GEMV-like broadcast — fine for the sample
    sizes SOGAIC uses (Φ centroids from ≤ a few hundred thousand sampled
    rows).
    """
    del n_local_trials  # greedy variant not needed at our sample sizes
    n = x.shape[0]
    x = x.astype(jnp.float32)

    k0, key = jax.random.split(key)
    first = jax.random.randint(k0, (), 0, n)
    init_centroid = x[first]

    def body(carry, step_key):
        min_d2, centroids, j = carry
        # Sample next centroid ∝ D², as in the paper.  log-space categorical.
        logits = jnp.where(min_d2 > 0, jnp.log(min_d2 + 1e-30), -jnp.inf)
        # Guard: if all distances are zero (duplicate-heavy sample) fall back
        # to uniform so sampling stays well-defined.
        logits = jnp.where(jnp.all(~jnp.isfinite(logits)), jnp.zeros_like(logits), logits)
        nxt = jax.random.categorical(step_key, logits)
        c_new = x[nxt]
        d2_new = jnp.sum((x - c_new[None, :]) ** 2, axis=-1)
        min_d2 = jnp.minimum(min_d2, d2_new)
        centroids = centroids.at[j].set(c_new)
        return (min_d2, centroids, j + 1), None

    d2_init = jnp.sum((x - init_centroid[None, :]) ** 2, axis=-1)
    centroids0 = jnp.zeros((k, x.shape[1]), jnp.float32).at[0].set(init_centroid)
    (_, centroids, _), _ = jax.lax.scan(
        body, (d2_init, centroids0, jnp.int32(1)), jax.random.split(key, k - 1)
    )
    return centroids


@functools.partial(
    jax.jit, static_argnames=("k", "max_iters", "batch_size", "init")
)
def kmeans_fit(
    key: jax.Array,
    x: jax.Array,
    k: int,
    *,
    max_iters: int = 25,
    tol: float = 1e-4,
    batch_size: int | None = None,
    init: str = "kmeans++",
) -> KMeansState:
    """Fit k-means on ``x`` (n, d) with ``k`` clusters.

    Full-batch Lloyd when ``batch_size is None``; mini-batch (Sculley 2010
    style, with per-centroid learning-rate 1/count) otherwise.  Empty
    clusters keep their previous centroid.

    Early stopping on centroid movement < ``tol`` is implemented with a
    ``while_loop`` so the compiled step count is data-dependent but bounded
    by ``max_iters``.
    """
    n, d = x.shape
    x = x.astype(jnp.float32)
    if init == "kmeans++":
        init_key, key = jax.random.split(key)
        centroids = kmeans_plus_plus_init(init_key, x, k)
    elif init == "random":
        init_key, key = jax.random.split(key)
        sel = jax.random.choice(init_key, n, (k,), replace=False)
        centroids = x[sel]
    else:  # pragma: no cover - config error
        raise ValueError(f"unknown init {init!r}")

    def full_batch_step(centroids):
        idx, d2 = assign_nearest(x, centroids)
        sums = jax.ops.segment_sum(x, idx, num_segments=k)
        counts = jax.ops.segment_sum(jnp.ones((n,), jnp.float32), idx, num_segments=k)
        new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), centroids)
        return new, jnp.mean(d2)

    def minibatch_step(centroids, counts, step_key):
        sel = jax.random.randint(step_key, (batch_size,), 0, n)
        xb = x[sel]
        idx, d2 = assign_nearest(xb, centroids)
        b_sums = jax.ops.segment_sum(xb, idx, num_segments=k)
        b_counts = jax.ops.segment_sum(
            jnp.ones((batch_size,), jnp.float32), idx, num_segments=k
        )
        counts = counts + b_counts
        lr = jnp.where(counts > 0, b_counts / jnp.maximum(counts, 1.0), 0.0)
        new = centroids + lr[:, None] * (
            jnp.where(
                b_counts[:, None] > 0,
                b_sums / jnp.maximum(b_counts[:, None], 1.0),
                centroids,
            )
            - centroids
        )
        return new, counts, jnp.mean(d2)

    if batch_size is None:

        def cond(state):
            _, shift, i, _ = state
            return jnp.logical_and(i < max_iters, shift > tol)

        def body(state):
            centroids, _, i, _ = state
            new, inertia = full_batch_step(centroids)
            shift = jnp.max(jnp.sum((new - centroids) ** 2, axis=-1))
            return new, shift, i + 1, inertia

        centroids, _, n_iter, inertia = jax.lax.while_loop(
            cond, body, (centroids, jnp.float32(jnp.inf), jnp.int32(0), jnp.float32(0.0))
        )
    else:

        def body(carry, step_key):
            centroids, counts = carry
            new, counts, inertia = minibatch_step(centroids, counts, step_key)
            return (new, counts), inertia

        (centroids, _), inertias = jax.lax.scan(
            body,
            (centroids, jnp.zeros((k,), jnp.float32)),
            jax.random.split(key, max_iters),
        )
        inertia = inertias[-1]
        n_iter = jnp.int32(max_iters)

    return KMeansState(centroids=centroids, inertia=inertia, n_iter=n_iter)
