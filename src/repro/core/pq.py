"""Product quantization — trained once, encoded in the partition chunk pipeline.

The paper (§2.1, Fig. 1c) runs quantization encoding *in parallel with* the
vector-assignment stage so each vector is encoded exactly once and the codes
are merged downstream, instead of DiskANN's separate sequential pass.  The
pipeline in :mod:`repro.core.pipeline` calls :func:`pq_encode` on the same
device-resident chunk that :func:`repro.core.partition.assign_chunk` consumes
— one HBM round-trip for both stages.

Layout: D-dim vectors split into M contiguous subspaces of D/M dims, each
with a 256-entry codebook (uint8 codes).  ADC (asymmetric distance
computation) builds per-query lookup tables so graph-search distance
evaluations become M table gathers instead of D-dim float ops.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.kmeans import kmeans_fit, pairwise_sq_l2

__all__ = ["PQCodebook", "pq_train", "pq_encode", "pq_decode", "adc_lookup_tables", "adc_distances"]


class PQCodebook(NamedTuple):
    """(M, n_codes, D/M) float32 codebooks."""

    codebooks: jax.Array

    @property
    def m(self) -> int:
        return self.codebooks.shape[0]

    @property
    def n_codes(self) -> int:
        return self.codebooks.shape[1]

    @property
    def dsub(self) -> int:
        return self.codebooks.shape[2]


def pq_train(
    key: jax.Array,
    x: jax.Array,
    m: int,
    *,
    n_codes: int = 256,
    iters: int = 20,
) -> PQCodebook:
    """Train per-subspace k-means codebooks on a sample ``x`` (n, d)."""
    n, d = x.shape
    if d % m != 0:
        raise ValueError(f"d={d} not divisible by M={m}")
    dsub = d // m
    xs = x.reshape(n, m, dsub).transpose(1, 0, 2)  # (M, n, dsub)

    def fit_one(k, xsub):
        return kmeans_fit(k, xsub, n_codes, max_iters=iters, init="random").centroids

    codebooks = jax.vmap(fit_one)(jax.random.split(key, m), xs)
    return PQCodebook(codebooks=codebooks)


@functools.partial(jax.jit, static_argnames=())
def pq_encode(x: jax.Array, codebook: PQCodebook) -> jax.Array:
    """Encode ``x`` (n, d) → codes (n, M) uint8.

    Per-subspace distance + argmin; the TPU hot path is the fused Pallas
    kernel in :mod:`repro.kernels` (``pq_encode``) — this jnp form is the
    oracle / CPU path and is numerically identical.
    """
    n, d = x.shape
    m, n_codes, dsub = codebook.codebooks.shape
    xs = x.reshape(n, m, dsub).transpose(1, 0, 2)  # (M, n, dsub)

    def enc_one(xsub, cb):
        return jnp.argmin(pairwise_sq_l2(xsub, cb), axis=-1)

    codes = jax.vmap(enc_one)(xs, codebook.codebooks)  # (M, n)
    return codes.T.astype(jnp.uint8)


@jax.jit
def pq_decode(codes: jax.Array, codebook: PQCodebook) -> jax.Array:
    """codes (n, M) uint8 → approximate vectors (n, d)."""
    m = codebook.m

    def dec_one(codes_m, cb):
        return cb[codes_m.astype(jnp.int32)]

    parts = jax.vmap(dec_one)(codes.T, codebook.codebooks)  # (M, n, dsub)
    return parts.transpose(1, 0, 2).reshape(codes.shape[0], -1)


@jax.jit
def adc_lookup_tables(queries: jax.Array, codebook: PQCodebook) -> jax.Array:
    """Per-query ADC tables: (q, M, n_codes) squared distances."""
    q, d = queries.shape
    m, n_codes, dsub = codebook.codebooks.shape
    qs = queries.reshape(q, m, dsub).transpose(1, 0, 2)  # (M, q, dsub)

    def tab_one(qsub, cb):
        return pairwise_sq_l2(qsub, cb)  # (q, n_codes)

    tabs = jax.vmap(tab_one)(qs, codebook.codebooks)  # (M, q, n_codes)
    return tabs.transpose(1, 0, 2)


@jax.jit
def adc_distances(luts: jax.Array, codes: jax.Array) -> jax.Array:
    """Approximate squared distances: luts (q, M, 256) × codes (n, M) → (q, n).

    On TPU the gather is reformulated per subspace as a one-hot contraction
    when ``n`` is large (MXU-friendly); jnp.take_along_axis is the oracle.
    """
    c = codes.astype(jnp.int32)  # (n, M)

    def per_query(lut):  # lut (M, 256)
        return jnp.take_along_axis(lut.T, c, axis=0).sum(axis=1)  # (n,)

    return jax.vmap(per_query)(luts)
