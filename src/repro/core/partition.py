"""Algorithm 1 — Overload-Aware Adaptive Vector Assignment (paper §2.1).

Two implementations:

``assign_reference``
    Exact, sequential NumPy transcription of the paper's pseudocode, with
    globally-sequential capacity counters.  Used as the semantic oracle in
    tests and for small builds.

``assign_chunk``
    Batched, jit-compiled JAX version used by the production pipeline.
    Vectors are processed in chunks; capacity counters are snapshotted at
    chunk entry and enforced *exactly* by an intra-chunk rank-by-distance
    repair pass (closest requests win), with the counter state synchronised
    between chunks (and, distributed, psum'd across the data axes).  The
    paper itself parallelises assignment ("the vector assignment process is
    independent"), so globally-sequential counters do not exist on their
    cluster either; the invariants that matter — ``|s_i| ≤ Γ`` always, every
    vector in ≥1 and ≤Ω subsets, acceptance follows the ε-relaxed
    distance-ordered walk — hold bit-exactly.  See DESIGN.md §3.

The chunk walk only inspects each vector's ``k_cand`` nearest centroids
(the full Φ-wide walk almost never progresses past a handful of candidates;
the tail only matters when *every* near centroid is full).  Vectors that
exhaust their candidate list unassigned are returned to the host driver,
which resolves them exactly against the full centroid set — a path that is
cold by construction (Φ·Γ ≥ Ω·N guarantees spare capacity somewhere).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kmeans import pairwise_sq_l2

__all__ = [
    "PartitionConfig",
    "AssignChunkResult",
    "estimate_num_partitions",
    "assign_reference",
    "assign_chunk",
    "partition_all",
    "PartitionResult",
]


@dataclasses.dataclass(frozen=True)
class PartitionConfig:
    """Parameters of the overload-aware partitioning stage.

    Attributes mirror the paper's symbols:
      gamma   Γ — max vectors per subset (container memory bound)
      omega   Ω — max subsets a vector may join (≥ 2)
      eps     ε — adaptive relaxation (> 1); small for uniform data, larger
                  for structured data (paper uses 1.8 on their datasets)
    """

    gamma: int
    omega: int = 4
    eps: float = 1.8
    k_cand: int = 32
    chunk_size: int = 8192
    n_repair: int = 2
    sample_size: int = 65536
    kmeans_iters: int = 25
    seed: int = 0

    def __post_init__(self) -> None:
        if self.omega < 2:
            raise ValueError("Ω must be ≥ 2 (paper, Algorithm 1 requirements)")
        if self.eps <= 1.0:
            raise ValueError("ε must be > 1")
        if self.gamma < 1:
            raise ValueError("Γ must be ≥ 1")


def estimate_num_partitions(n: int, gamma: int, omega: int) -> int:
    """Φ = ⌈Ω·N/Γ⌉ — minimum partition count for worst-case imbalance."""
    return max(1, math.ceil(omega * n / gamma))


# ---------------------------------------------------------------------------
# Exact sequential oracle (paper pseudocode, line-for-line)
# ---------------------------------------------------------------------------


def assign_reference(
    x: np.ndarray,
    centroids: np.ndarray,
    *,
    omega: int,
    eps: float,
    gamma: int,
    order: np.ndarray | None = None,
) -> tuple[list[list[int]], np.ndarray]:
    """Sequential Algorithm 1.  Returns (per-vector subset lists, sizes)."""
    n = x.shape[0]
    phi = centroids.shape[0]
    sizes = np.zeros(phi, dtype=np.int64)
    assignments: list[list[int]] = [[] for _ in range(n)]
    idx_order = np.arange(n) if order is None else np.asarray(order)
    for v in idx_order:
        d = np.sqrt(np.maximum(((x[v][None, :] - centroids) ** 2).sum(-1), 0.0))
        queue = np.argsort(d, kind="stable")
        olp_cnt = 0
        olp_factor = 0
        acc_dist = 0.0
        cur_avg = np.inf
        for i in queue:  # 'while Q not empty and curOLPCnt < Ω'
            if olp_cnt >= omega:
                break
            di = float(d[i])
            if di <= eps * cur_avg:  # line 9 (inf on first iteration)
                olp_factor += 1  # line 10
                acc_dist += di  # line 11
                cur_avg = acc_dist / olp_factor  # line 12
                if sizes[i] < gamma:  # line 13
                    olp_cnt += 1  # line 14
                    sizes[i] += 1
                    assignments[v].append(int(i))  # line 15
                else:
                    cur_avg = np.inf  # line 17 — reset on overload
        assert assignments[v], "Φ·Γ ≥ Ω·N guarantees at least one landing spot"
    return assignments, sizes


# ---------------------------------------------------------------------------
# Batched JAX implementation
# ---------------------------------------------------------------------------


class AssignChunkResult(NamedTuple):
    accept: jax.Array  # (B, K) bool — final accepted (vector, candidate) slots
    cand_idx: jax.Array  # (B, K) int32 — centroid id per slot
    cand_dist: jax.Array  # (B, K) float32 — L2 distance per slot
    added: jax.Array  # (Φ,) int32 — per-centroid additions from this chunk
    unassigned: jax.Array  # (B,) bool — vectors needing host fallback
    overlap: jax.Array  # (B,) int32 — accepted subset count per vector


def _walk(dists: jax.Array, full: jax.Array, omega: int, eps) -> jax.Array:
    """The ε-relaxed distance walk for one vector (scan over K candidates).

    ``dists`` (K,) ascending; ``full`` (K,) bool — candidate's subset full at
    snapshot.  Returns accept mask (K,).  Mirrors pseudocode lines 7-19.
    """

    def body(carry, inp):
        olp_cnt, olp_factor, acc_dist, cur_avg = carry
        d, is_full = inp
        active = olp_cnt < omega  # while-loop condition (line 7)
        dist_ok = d <= eps * cur_avg  # line 9
        consider = active & dist_ok
        olp_factor = jnp.where(consider, olp_factor + 1, olp_factor)
        acc_dist = jnp.where(consider, acc_dist + d, acc_dist)
        cur_avg = jnp.where(consider, acc_dist / jnp.maximum(olp_factor, 1), cur_avg)
        take = consider & ~is_full  # line 13
        olp_cnt = jnp.where(take, olp_cnt + 1, olp_cnt)
        cur_avg = jnp.where(consider & is_full, jnp.inf, cur_avg)  # line 17
        return (olp_cnt, olp_factor, acc_dist, cur_avg), take

    # Derive the init carry from the inputs so it inherits their varying
    # manual axes under shard_map (plain constants would fail the vma check).
    zf = dists[0] * 0.0
    zi = zf.astype(jnp.int32)
    init = (zi, zi, zf, zf + jnp.inf)
    _, take = jax.lax.scan(body, init, (dists, full))
    return take


def _enforce_capacity(
    accept: jax.Array,
    cand_idx: jax.Array,
    cand_dist: jax.Array,
    remaining: jax.Array,
    phi: int,
) -> jax.Array:
    """Keep, per centroid, only the ``remaining[c]`` closest accepted requests.

    Rank-by-distance within each centroid group via a two-key stable sort
    (distance, then centroid) + segment-relative positions; O(BK log BK),
    no (B, Φ) densification.
    """
    bk = accept.size
    flat_accept = accept.reshape(-1)
    flat_cid = cand_idx.reshape(-1)
    flat_dist = cand_dist.reshape(-1)

    # Stable sort by distance; rejected entries pushed to the end.
    key1 = jnp.where(flat_accept, flat_dist, jnp.inf)
    order1 = jnp.argsort(key1, stable=True)
    cid1 = jnp.where(flat_accept, flat_cid, phi)[order1]  # sentinel Φ = invalid
    # Stable sort by centroid id → groups contiguous, distance-ordered inside.
    order2 = jnp.argsort(cid1, stable=True)
    cid2 = cid1[order2]

    pos = jnp.arange(bk, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool), cid2[1:] != cid2[:-1]])
    group_start = jax.lax.cummax(jnp.where(is_start, pos, 0))
    rank = pos - group_start
    rem = remaining[jnp.minimum(cid2, phi - 1)]
    keep_sorted = (cid2 < phi) & (rank < rem)

    final_slot = order1[order2]  # position in original flat layout
    keep_flat = jnp.zeros((bk,), bool).at[final_slot].set(keep_sorted)
    return keep_flat.reshape(accept.shape)


@functools.partial(
    jax.jit, static_argnames=("omega", "gamma", "k_cand", "n_repair")
)
def assign_chunk(
    x: jax.Array,
    centroids: jax.Array,
    sizes: jax.Array,
    valid: jax.Array | None = None,
    *,
    omega: int,
    eps: float,
    gamma: int,
    k_cand: int = 32,
    n_repair: int = 2,
) -> AssignChunkResult:
    """Chunk-synchronous Algorithm 1 over a chunk of ``B`` vectors.

    ``sizes`` (Φ,) int32 — subset sizes at chunk entry.  Capacity Γ is
    enforced exactly: the walk runs against the snapshot, then the repair
    pass keeps only the closest requests per centroid within the remaining
    budget, then up to ``n_repair`` re-walks rescue vectors that lost all
    their slots (with the updated counts).  Anything still unassigned is
    flagged for the host's exact fallback.  ``valid`` masks padding rows in
    the final (ragged) chunk so they neither claim capacity nor report as
    unassigned.
    """
    phi = centroids.shape[0]
    k_cand = min(k_cand, phi)
    if valid is None:
        valid = jnp.ones((x.shape[0],), bool)
    d2 = pairwise_sq_l2(x, centroids)  # (B, Φ) — Pallas fused on TPU
    neg_top, cand_idx = jax.lax.top_k(-d2, k_cand)
    cand_idx = cand_idx.astype(jnp.int32)
    cand_dist = jnp.sqrt(jnp.maximum(-neg_top, 0.0))  # ascending L2

    accept = jnp.zeros(cand_dist.shape, bool)
    added = jnp.zeros((phi,), jnp.int32)
    need = valid  # vectors still fully unassigned

    for _ in range(1 + n_repair):
        sizes_eff = sizes + added
        full = sizes_eff[cand_idx] >= gamma  # (B, K) snapshot
        want = jax.vmap(_walk, in_axes=(0, 0, None, None))(
            cand_dist, full, omega, jnp.float32(eps)
        )
        want = want & need[:, None]
        remaining = jnp.maximum(gamma - sizes_eff, 0).astype(jnp.int32)
        kept = _enforce_capacity(want, cand_idx, cand_dist, remaining, phi)
        accept = accept | kept
        added = added + jax.ops.segment_sum(
            kept.reshape(-1).astype(jnp.int32),
            cand_idx.reshape(-1),
            num_segments=phi,
        )
        need = valid & ~jnp.any(accept, axis=1)

    overlap = jnp.sum(accept, axis=1).astype(jnp.int32)
    return AssignChunkResult(
        accept=accept,
        cand_idx=cand_idx,
        cand_dist=cand_dist,
        added=added,
        unassigned=need & valid,
        overlap=overlap,
    )


# ---------------------------------------------------------------------------
# Host driver — streams chunks, resolves rare fallbacks exactly
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PartitionResult:
    """Output of the partitioning stage.

    ``assign_idx`` (N, Ω) int32 — centroid ids per vector, -1 padded.
    ``sizes`` (Φ,) int64 — final subset sizes (all ≤ Γ).
    ``avg_overlap`` — the paper's §3.2.1 metric (their 1.93 @ Ω=4, ε=1.8).
    ``fallback_count`` — vectors resolved by the host's exact cold path.
    """

    assign_idx: np.ndarray
    sizes: np.ndarray
    avg_overlap: float
    fallback_count: int

    def members(self, subset: int) -> np.ndarray:
        return np.nonzero((self.assign_idx == subset).any(axis=1))[0]

    def all_members(self) -> list[np.ndarray]:
        phi = len(self.sizes)
        flat = self.assign_idx.reshape(-1)
        vec = np.repeat(np.arange(self.assign_idx.shape[0]), self.assign_idx.shape[1])
        valid = flat >= 0
        order = np.argsort(flat[valid], kind="stable")
        svals = flat[valid][order]
        svecs = vec[valid][order]
        bounds = np.searchsorted(svals, np.arange(phi + 1))
        return [svecs[bounds[i] : bounds[i + 1]] for i in range(phi)]


def _host_fallback(
    xi: np.ndarray, centroids: np.ndarray, sizes: np.ndarray, gamma: int
) -> int:
    """Exact nearest non-full centroid for one vector (cold path)."""
    d = ((xi[None, :] - centroids) ** 2).sum(-1)
    d[sizes >= gamma] = np.inf
    j = int(np.argmin(d))
    if not np.isfinite(d[j]):  # pragma: no cover — impossible if Φ·Γ ≥ N
        raise RuntimeError("all subsets full; Γ/Ω misconfigured")
    return j


def partition_all(
    x: np.ndarray,
    centroids: np.ndarray,
    cfg: PartitionConfig,
    *,
    progress: bool = False,
) -> PartitionResult:
    """Stream ``x`` through ``assign_chunk`` and assemble the full result."""
    n = x.shape[0]
    phi = centroids.shape[0]
    omega = cfg.omega
    sizes = np.zeros((phi,), np.int32)
    assign_idx = np.full((n, omega), -1, np.int32)
    fallbacks = 0
    centroids_j = jnp.asarray(centroids, jnp.float32)

    for lo in range(0, n, cfg.chunk_size):
        hi = min(lo + cfg.chunk_size, n)
        xc = x[lo:hi]
        pad = 0
        if hi - lo < cfg.chunk_size and n > cfg.chunk_size:
            pad = cfg.chunk_size - (hi - lo)
            xc = np.concatenate([xc, np.zeros((pad, x.shape[1]), x.dtype)], axis=0)
        valid = np.ones((xc.shape[0],), bool)
        if pad:
            valid[hi - lo :] = False
        res = assign_chunk(
            jnp.asarray(xc, jnp.float32),
            centroids_j,
            jnp.asarray(sizes),
            jnp.asarray(valid),
            omega=omega,
            eps=cfg.eps,
            gamma=cfg.gamma,
            k_cand=cfg.k_cand,
            n_repair=cfg.n_repair,
        )
        accept = np.asarray(res.accept)
        cand = np.asarray(res.cand_idx)
        unassigned = np.asarray(res.unassigned)
        if pad:
            accept, cand, unassigned = accept[: hi - lo], cand[: hi - lo], unassigned[: hi - lo]
        # Scatter accepted assignments into the (N, Ω) table.
        for b in range(hi - lo):
            row = cand[b][accept[b]][:omega]
            assign_idx[lo + b, : len(row)] = row
            sizes[row] += 1
            if unassigned[b]:
                j = _host_fallback(x[lo + b].astype(np.float64), centroids, sizes, cfg.gamma)
                assign_idx[lo + b, 0] = j
                sizes[j] += 1
                fallbacks += 1
        if progress:  # pragma: no cover
            print(f"partition: {hi}/{n} sizes max={sizes.max()} fallbacks={fallbacks}")

    assert sizes.max() <= cfg.gamma, "capacity invariant violated"
    valid = (assign_idx >= 0).sum(axis=1)
    assert (valid >= 1).all(), "every vector must land in ≥1 subset"
    return PartitionResult(
        assign_idx=assign_idx,
        sizes=sizes.astype(np.int64),
        avg_overlap=float(valid.mean()),
        fallback_count=fallbacks,
    )
