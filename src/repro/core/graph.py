"""TPU-native ANNS subgraph construction.

The paper builds each subset's subgraph independently with an existing
graph library (Vamana/HNSW/SPTAG-style).  Those builders are incremental
pointer-chasing CPU algorithms; on TPU we re-derive the build around the
MXU (DESIGN.md §3):

  1. **Tiled exact kNN** over the subset — fused distance + top-k Pallas
     kernel (``repro.kernels``), query-block × db-block tiles sized for
     VMEM.  For subsets capped at Γ this is exact and perfectly regular.
  2. **RobustPrune** (Vamana's α-diversification) vectorized across nodes:
     per node a fixed-C candidate list, a (C, C) candidate-candidate
     distance tile, and a ``fori_loop`` greedy selection.
  3. **Reverse-edge pass** — backlinks gathered by sorting the edge list by
     destination, then a second vectorized prune.
  4. Optional **beam refinement rounds** (classic Vamana second pass):
     re-search each node from the medoid with the current graph and
     re-prune against the visited pool.

All functions are jit-compiled with static shapes; adjacency is a dense
``(n, R) int32`` with ``-1`` padding throughout the system.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kmeans import pairwise_sq_l2

__all__ = [
    "find_medoid",
    "build_knn_graph",
    "robust_prune",
    "prune_candidate_lists",
    "add_reverse_edges",
    "build_subgraph",
    "vamana_refine",
]


@jax.jit
def find_medoid(x: jax.Array) -> jax.Array:
    """Index of the vector closest to the dataset centroid (graph entry)."""
    mean = jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True)
    return jnp.argmin(pairwise_sq_l2(x, mean)[:, 0])


def _l2_topk_block(q: jax.Array, db: jax.Array, k: int, self_offset: int | None):
    """Distances from query block to full db + top-k (ascending).

    ``self_offset``: global row offset of the query block inside ``db`` —
    used to mask self-matches when building a kNN graph over one set.
    Dispatches to the fused Pallas kernel on TPU (see repro.kernels.ops).
    """
    d2 = pairwise_sq_l2(q, db)
    if self_offset is not None:
        b = q.shape[0]
        rows = jnp.arange(b)
        d2 = d2.at[rows, rows + self_offset].set(jnp.inf)
    neg, idx = jax.lax.top_k(-d2, k)
    return jnp.sqrt(jnp.maximum(-neg, 0.0)), idx.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "block_q"))
def build_knn_graph(
    x: jax.Array, k: int, *, block_q: int = 512, n_valid: jax.Array | None = None
):
    """Exact kNN graph over ``x`` (n, d) → (dists (n, k), idx (n, k) int32).

    Tiled over query blocks; each block computes a (B, n) distance tile and
    keeps its top-k — the memory-bound pattern the fused Pallas kernel
    collapses to O(B·k) HBM writes on TPU.

    ``n_valid``: number of real rows when ``x`` is padded to a bucketed
    shape — columns ≥ n_valid get ∞ distance (never selected); rows ≥
    n_valid produce garbage that the caller discards.
    """
    n, d = x.shape
    k = min(k, n - 1)
    n_blocks = -(-n // block_q)
    n_pad = n_blocks * block_q
    xq = jnp.pad(x, ((0, n_pad - n), (0, 0)))
    xq = xq.reshape(n_blocks, block_q, d)
    offsets = jnp.arange(n_blocks) * block_q
    nv = n if n_valid is None else n_valid

    def one_block(args):
        q, off = args
        d2 = pairwise_sq_l2(q, x)
        rows = jnp.arange(block_q)
        in_range = rows + off < n
        cols = jnp.arange(n)[None, :]
        d2 = jnp.where((rows[:, None] + off) == cols, jnp.inf, d2)
        d2 = jnp.where(cols < nv, d2, jnp.inf)
        d2 = jnp.where(in_range[:, None], d2, jnp.inf)
        neg, idx = jax.lax.top_k(-d2, k)
        return jnp.sqrt(jnp.maximum(-neg, 0.0)), idx.astype(jnp.int32)

    dists, idx = jax.lax.map(one_block, (xq, offsets))
    return dists.reshape(n_pad, k)[:n], idx.reshape(n_pad, k)[:n]


def _prune_one(d_pc: jax.Array, d_cc: jax.Array, valid: jax.Array, r: int, alpha: float):
    """RobustPrune for one node.

    d_pc (C,) candidate→node distances; d_cc (C, C) candidate↔candidate;
    valid (C,) mask.  Greedy: take closest alive candidate j, kill every c
    with α·d(j, c) ≤ d(p, c).  Returns (sel (R,) int32 into candidates, -1
    padded).
    """
    c = d_pc.shape[0]

    def body(t, carry):
        alive, sel = carry
        masked = jnp.where(alive, d_pc, jnp.inf)
        j = jnp.argmin(masked)
        ok = jnp.isfinite(masked[j])
        sel = sel.at[t].set(jnp.where(ok, j.astype(jnp.int32), -1))
        kill = alpha * d_cc[j] <= d_pc  # includes j itself (d_cc[j,j]=0)
        alive = jnp.where(ok, alive & ~kill, alive)
        return alive, sel

    alive0 = valid & (d_pc < jnp.inf)
    # init derived from inputs so it inherits varying manual axes under
    # shard_map (a plain constant would fail the vma check)
    sel0 = jnp.full((r,), -1, jnp.int32) + (d_pc[0] * 0.0).astype(jnp.int32)
    _, sel = jax.lax.fori_loop(0, r, body, (alive0, sel0))
    return sel


@functools.partial(jax.jit, static_argnames=("r", "block"))
def _prune_blocks(
    x: jax.Array,
    node_idx: jax.Array,
    cand_idx: jax.Array,
    alpha: jax.Array,
    r: int,
    block: int,
):
    """Inner jitted prune; expects ``m % block == 0`` (wrapper pads)."""
    m, c = cand_idx.shape
    n_blocks = m // block
    node_p = node_idx
    cand_p = cand_idx

    def one_block(args):
        nodes, cands = args  # (B,), (B, C)
        # Dedup within each row: sort by id, mask repeats, also mask self.
        sorted_c = jnp.sort(cands, axis=1)
        dup = jnp.concatenate(
            [jnp.zeros((block, 1), bool), sorted_c[:, 1:] == sorted_c[:, :-1]], axis=1
        )
        order = jnp.argsort(cands, axis=1)
        # scatter dup flags back to original positions
        inv = jnp.argsort(order, axis=1)
        dup_orig = jnp.take_along_axis(dup, inv, axis=1)
        valid = (cands >= 0) & ~dup_orig & (cands != nodes[:, None])
        safe = jnp.maximum(cands, 0)
        pv = x[nodes]  # (B, d)
        cv = x[safe]  # (B, C, d)
        d_pc = jnp.sqrt(
            jnp.maximum(jnp.sum((cv - pv[:, None, :]) ** 2, axis=-1), 0.0)
        )
        d_pc = jnp.where(valid, d_pc, jnp.inf)
        d_cc = jax.vmap(lambda v: jnp.sqrt(jnp.maximum(pairwise_sq_l2(v, v), 0.0)))(cv)
        sel = jax.vmap(_prune_one, in_axes=(0, 0, 0, None, None))(
            d_pc, d_cc, valid, r, alpha
        )  # (B, R) slots into candidate lists
        out = jnp.where(sel >= 0, jnp.take_along_axis(safe, jnp.maximum(sel, 0), axis=1), -1)
        return out.astype(jnp.int32)

    rows = jax.lax.map(
        one_block, (node_p.reshape(n_blocks, block), cand_p.reshape(n_blocks, block, c))
    )
    return rows.reshape(m, r)


def prune_candidate_lists(
    x: jax.Array,
    node_idx: jax.Array,
    cand_idx: jax.Array,
    r: int,
    *,
    alpha: float = 1.2,
    block: int = 256,
):
    """Vectorized RobustPrune over many nodes.

    ``node_idx`` (m,) nodes being pruned; ``cand_idx`` (m, C) candidate node
    ids (-1 pad, may contain duplicates — deduped here).  Returns adjacency
    rows (m, R) int32 of *global* node ids (-1 pad).

    Host wrapper: pads ``m`` up to a power-of-two number of blocks before
    the inner jit, so the wildly varying row counts coming from merge
    overlap regions and subset buckets all land on O(log) compiled shapes.
    """
    m, c = cand_idx.shape
    block = int(min(block, max(8, m)))
    n_blocks = -(-m // block)
    if n_blocks > 1:
        n_blocks = 1 << (n_blocks - 1).bit_length()
    m_pad = n_blocks * block
    node_p = jnp.pad(jnp.asarray(node_idx), (0, m_pad - m))
    cand_p = jnp.pad(
        jnp.asarray(cand_idx), ((0, m_pad - m), (0, 0)), constant_values=-1
    )
    out = _prune_blocks(x, node_p, cand_p, jnp.float32(alpha), r, block)
    return out[:m]


def robust_prune(
    x: jax.Array, node_idx: jax.Array, cand_idx: jax.Array, r: int, *, alpha: float = 1.2
) -> jax.Array:
    """Single-call RobustPrune (thin wrapper, block auto-sized)."""
    block = int(min(256, max(8, node_idx.shape[0])))
    return prune_candidate_lists(x, node_idx, cand_idx, r, alpha=alpha, block=block)


@functools.partial(jax.jit, static_argnames=("r", "rev_cap"))
def add_reverse_edges(
    x: jax.Array, adj: jax.Array, r: int, *, alpha: float = 1.2, rev_cap: int = 32
):
    """Backlink pass: for every edge p→q, propose q→p, then re-prune rows.

    Reverse candidates are grouped by destination via a stable sort of the
    edge list (no scatter contention), capped at ``rev_cap`` backlinks per
    node, concatenated with existing rows, and re-pruned to degree R.
    """
    n = adj.shape[0]
    src = jnp.repeat(jnp.arange(n, dtype=jnp.int32), adj.shape[1])
    dst = adj.reshape(-1)
    valid = dst >= 0
    dst_key = jnp.where(valid, dst, n)  # invalid → sentinel end
    order = jnp.argsort(dst_key, stable=True)
    dst_s = dst_key[order]
    src_s = src[order]
    pos = jnp.arange(dst_s.shape[0], dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool), dst_s[1:] != dst_s[:-1]])
    group_start = jax.lax.cummax(jnp.where(is_start, pos, 0))
    slot = pos - group_start
    keep = (dst_s < n) & (slot < rev_cap)
    # Scatter capped backlinks into (n, rev_cap); rejected entries are
    # redirected out of bounds and dropped.
    rev = jnp.full((n, rev_cap), -1, jnp.int32)
    rev = rev.at[
        jnp.where(keep, dst_s, n), jnp.where(keep, slot, 0)
    ].set(src_s, mode="drop")
    cands = jnp.concatenate([adj, rev], axis=1)
    return prune_candidate_lists(
        x, jnp.arange(n, dtype=jnp.int32), cands, r, alpha=alpha, block=min(256, n)
    )


def build_subgraph(
    x: jax.Array,
    r: int = 32,
    *,
    alpha: float = 1.2,
    knn_k: int | None = None,
    rev_cap: int | None = None,
    block_q: int = 512,
    n_valid: int | jax.Array | None = None,
) -> jax.Array:
    """Build one subset's subgraph: exact kNN → RobustPrune → reverse pass.

    Returns adjacency (n, R) int32 with -1 padding.  When ``x`` is padded
    to a bucketed shape pass ``n_valid``: padding rows never appear as
    neighbors and their own rows come back all -1.
    """
    n = x.shape[0]
    knn_k = knn_k if knn_k is not None else min(max(2 * r, r + 16), max(n - 1, 1))
    rev_cap = rev_cap if rev_cap is not None else r
    nv = None if n_valid is None else jnp.asarray(n_valid, jnp.int32)
    knn_d, knn_idx = build_knn_graph(x, knn_k, block_q=min(block_q, n), n_valid=nv)
    if nv is not None:
        knn_idx = jnp.where(jnp.isfinite(knn_d), knn_idx, -1)
    adj = prune_candidate_lists(
        x, jnp.arange(n, dtype=jnp.int32), knn_idx, r, alpha=alpha, block=min(256, n)
    )
    if nv is not None:
        adj = jnp.where(jnp.arange(n)[:, None] < nv, adj, -1)
    adj = add_reverse_edges(x, adj, r, alpha=alpha, rev_cap=rev_cap)
    if nv is not None:
        adj = jnp.where(jnp.arange(n)[:, None] < nv, adj, -1)
    return adj


def vamana_refine(
    x: jax.Array,
    adj: jax.Array,
    r: int,
    *,
    alpha: float = 1.2,
    beam_l: int = 48,
    max_hops: int = 48,
    rounds: int = 1,
    batch: int = 512,
) -> jax.Array:
    """Vamana-style second pass: re-search every node through the current
    graph and re-prune against the visited pool (classic DiskANN round,
    batched).  Improves long-range navigability beyond the kNN-local
    neighborhoods; used by the pipeline when ``refine_rounds > 0``.
    """
    from repro.core.search import beam_search

    n = x.shape[0]
    medoid = find_medoid(x)
    for _ in range(rounds):
        new_rows = []
        for lo in range(0, n, batch):
            hi = min(lo + batch, n)
            res = beam_search(
                x, adj, x[lo:hi], medoid, k=beam_l, beam_l=beam_l,
                max_hops=max_hops,
            )
            # candidate pool: beam results ∪ expansion history ∪ current row
            cands = jnp.concatenate(
                [res.ids, res.visited, adj[lo:hi]], axis=1
            )
            rows = prune_candidate_lists(
                x, jnp.arange(lo, hi, dtype=jnp.int32), cands, r, alpha=alpha,
            )
            new_rows.append(rows)
        adj = jnp.concatenate(new_rows, axis=0)
        adj = add_reverse_edges(x, adj, r, alpha=alpha, rev_cap=r)
    return adj


def graph_stats(adj: np.ndarray) -> dict:
    """Host-side diagnostics: degree distribution + connectivity (union-find)."""
    adj = np.asarray(adj)
    n, r = adj.shape
    deg = (adj >= 0).sum(axis=1)
    parent = np.arange(n)

    def find(a):
        while parent[a] != a:
            parent[a] = parent[parent[a]]
            a = parent[a]
        return a

    for u in range(n):
        for v in adj[u]:
            if v >= 0:
                ru, rv = find(u), find(int(v))
                if ru != rv:
                    parent[ru] = rv
    n_comp = len({find(u) for u in range(n)})
    return {
        "n": int(n),
        "degree_mean": float(deg.mean()),
        "degree_min": int(deg.min()),
        "degree_max": int(deg.max()),
        "n_components": int(n_comp),
    }
