"""Load-balancing task scheduling (paper §2.2, Fig. 2a).

Static plan: **LPT** (longest processing time first) — subsets sorted by
size descending, each assigned to the least-loaded worker; with the linear
cost model (build time ∝ subset size, the paper's observation) this is the
classic (4/3 − 1/(3m))·OPT greedy.  Γ from the partitioning stage bounds
the largest task, so no container overloads — exactly why the paper can
use greedy LPT instead of BDSC/LSSP-class schedulers.

Dynamic runtime: :class:`ClusterScheduler` — an event-driven executor that
adds the properties a 1000+-node deployment needs:

  * **fault tolerance** — failed tasks are re-queued and re-assigned
  * **straggler mitigation** — tasks running > ``straggler_factor`` × the
    expected time get a speculative duplicate on the fastest idle worker;
    first completion wins, the loser is cancelled
  * **elasticity** — workers may join/leave between events; queued work is
    re-balanced

The scheduler is a host-side component (it decides *where* device work
runs); it is exercised directly by the build pipeline and the cluster
simulator (repro.distributed.cluster_sim) injects failures/stragglers.
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import defaultdict
from typing import Callable, Iterable

import numpy as np

__all__ = ["lpt_schedule", "ScheduledTask", "ClusterScheduler", "makespan_lower_bound"]


def lpt_schedule(costs: Iterable[float], n_workers: int):
    """LPT: sort tasks by cost desc; assign each to the least-loaded worker.

    Returns (assignment: list[list[task_idx]] per worker, makespan: float).
    """
    costs = list(costs)
    if n_workers < 1:
        raise ValueError("need at least one worker")
    order = sorted(range(len(costs)), key=lambda i: -costs[i])
    heap = [(0.0, w) for w in range(n_workers)]
    heapq.heapify(heap)
    assignment: list[list[int]] = [[] for _ in range(n_workers)]
    for t in order:
        load, w = heapq.heappop(heap)
        assignment[w].append(t)
        heapq.heappush(heap, (load + costs[t], w))
    makespan = max((sum(costs[t] for t in a) for a in assignment), default=0.0)
    return assignment, makespan


def makespan_lower_bound(costs: Iterable[float], n_workers: int) -> float:
    costs = list(costs)
    if not costs:
        return 0.0
    return max(sum(costs) / n_workers, max(costs))


@dataclasses.dataclass
class ScheduledTask:
    task_id: int
    cost: float  # predicted cost (∝ subset size for builds)
    priority: float = 0.0  # higher first (e.g. merge overlap count)
    payload: object = None
    attempts: int = 0
    speculative_of: int | None = None


@dataclasses.dataclass
class _Worker:
    worker_id: int
    speed: float = 1.0  # relative throughput
    alive: bool = True
    busy_until: float = 0.0
    current: ScheduledTask | None = None


class ClusterScheduler:
    """Event-driven dynamic scheduler with retries, speculation, elasticity.

    Time is virtual: the caller supplies a ``runner(task, worker_id)`` that
    returns the *actual* duration (the cluster simulator returns perturbed
    durations; the real pipeline returns measured wall time).  ``run()``
    advances a virtual clock over completion events — the standard
    list-scheduling discrete-event loop.
    """

    def __init__(
        self,
        n_workers: int,
        *,
        straggler_factor: float = 3.0,
        max_attempts: int = 4,
        speculation: bool = True,
    ) -> None:
        self.workers: dict[int, _Worker] = {
            w: _Worker(worker_id=w) for w in range(n_workers)
        }
        self.straggler_factor = straggler_factor
        self.max_attempts = max_attempts
        self.speculation = speculation
        self.log: list[dict] = []
        self._next_worker_id = n_workers

    # -- elasticity ---------------------------------------------------------
    def add_worker(self, speed: float = 1.0) -> int:
        wid = self._next_worker_id
        self._next_worker_id += 1
        self.workers[wid] = _Worker(worker_id=wid, speed=speed)
        return wid

    def remove_worker(self, worker_id: int) -> None:
        if worker_id in self.workers:
            self.workers[worker_id].alive = False

    # -- main loop ----------------------------------------------------------
    def run(
        self,
        tasks: list[ScheduledTask],
        runner: Callable[[ScheduledTask, int], float | None],
        *,
        on_complete: Callable[[ScheduledTask, int, float], None] | None = None,
    ) -> dict:
        """Execute all tasks; returns {makespan, per_worker_load, events}.

        ``runner`` returns the task's duration on that worker, or ``None``
        to signal a worker failure (task will be retried elsewhere).
        """
        # priority: higher priority first, then larger cost (LPT within class)
        queue = sorted(tasks, key=lambda t: (-t.priority, -t.cost))
        pending = list(queue)
        completed: dict[int, float] = {}
        running: list[tuple[float, int, ScheduledTask]] = []  # (finish, worker, task)
        clock = 0.0
        last_completion = 0.0
        expected: dict[int, float] = {}

        def idle_workers():
            busy = {w for _, w, _ in running}
            return [
                w
                for w, st in self.workers.items()
                if st.alive and w not in busy
            ]

        def launch(task: ScheduledTask, wid: int, now: float):
            task.attempts += 1
            dur = runner(task, wid)
            if dur is None:  # worker died mid-task
                self.workers[wid].alive = False
                self.log.append(
                    {"t": now, "ev": "worker_failed", "worker": wid, "task": task.task_id}
                )
                if task.attempts >= self.max_attempts:
                    raise RuntimeError(f"task {task.task_id} exceeded max attempts")
                pending.insert(0, task)
                return
            dur = dur / self.workers[wid].speed
            heapq.heappush(running, (now + dur, wid, task))
            expected.setdefault(task.task_id, task.cost)
            self.log.append(
                {"t": now, "ev": "launch", "worker": wid, "task": task.task_id, "dur": dur}
            )

        while pending or running:
            # fill idle workers
            for wid in idle_workers():
                if not pending:
                    break
                launch(pending.pop(0), wid, clock)
            if not running:
                if pending and not idle_workers():
                    raise RuntimeError("no alive workers remain")
                continue
            finish, wid, task = heapq.heappop(running)
            clock = max(clock, finish)
            base = task.speculative_of if task.speculative_of is not None else task.task_id
            if base in completed:
                # a speculative twin already finished; drop this copy
                self.log.append({"t": clock, "ev": "cancelled", "task": task.task_id})
                continue
            completed[base] = clock
            last_completion = clock
            self.log.append({"t": clock, "ev": "done", "worker": wid, "task": task.task_id})
            if on_complete is not None:
                on_complete(task, wid, clock)
            # straggler speculation: any running task past factor×expected?
            if self.speculation and pending == [] and running:
                for fin, w2, t2 in list(running):
                    base2 = t2.speculative_of if t2.speculative_of is not None else t2.task_id
                    if base2 in completed:
                        continue
                    exp = expected.get(t2.task_id, t2.cost)
                    if fin - clock > (self.straggler_factor - 1.0) * max(exp, 1e-9):
                        idle = idle_workers()
                        if idle:
                            dup = ScheduledTask(
                                task_id=-t2.task_id - 1,
                                cost=t2.cost,
                                priority=t2.priority,
                                payload=t2.payload,
                                speculative_of=base2,
                            )
                            launch(dup, idle[0], clock)
                            self.log.append(
                                {"t": clock, "ev": "speculate", "task": t2.task_id}
                            )

        loads = defaultdict(float)
        for ev in self.log:
            if ev["ev"] == "launch":
                loads[ev["worker"]] += ev["dur"]
        return {
            # makespan = time of the last real completion; abandoned
            # straggler attempts (first-finisher-wins losers) are killed,
            # not waited for
            "makespan": last_completion,
            "per_worker_load": dict(loads),
            "events": self.log,
            "n_completed": len(completed),
        }


def predict_build_cost(subset_size: int, dim: int, *, c0: float = 0.0, c1: float = 1.0) -> float:
    """Linear cost model t ≈ c0 + c1·n — the paper's 'near-linear
    relationship between ANNS graph construction time and dataset size'.
    Coefficients are fit online from completed tasks by the pipeline."""
    return c0 + c1 * float(subset_size) * float(dim) / 1e6


def fit_linear_cost(sizes: np.ndarray, times: np.ndarray) -> tuple[float, float]:
    """Least-squares (c0, c1) for the linear cost model; robust to n=1."""
    sizes = np.asarray(sizes, np.float64)
    times = np.asarray(times, np.float64)
    if len(sizes) < 2:
        c1 = float(times[0] / max(sizes[0], 1.0)) if len(sizes) else 1.0
        return 0.0, c1
    a = np.stack([np.ones_like(sizes), sizes], axis=1)
    coef, *_ = np.linalg.lstsq(a, times, rcond=None)
    return float(coef[0]), float(coef[1])
