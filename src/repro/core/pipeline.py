"""End-to-end SOGAIC build pipeline (paper §2, Fig. 1c + Fig. 2).

Stages (each checkpointed, each resumable):

  1. ``centroids``   sample → K-means → Φ = ⌈Ω·N/Γ⌉ centroids
  2. ``partition``   stream chunks through Algorithm 1 (+ fused PQ encode —
                     each vector encoded exactly once, in the same
                     device-resident pass, per Fig. 1c)
  3. ``build``       per-subset subgraph construction, LPT-scheduled across
                     the worker pool (ClusterScheduler: retries, speculation,
                     elasticity)
  4. ``merge``       agglomerative binary-tree merge, highest-overlap pairs
                     first, scheduled per round
  5. ``finalize``    medoid + final graph assembly → SOGAICIndex

The pipeline is host-orchestrated; every hot loop (distances, walks, prunes,
searches) is a jitted JAX function, which is exactly how the distributed
deployment maps it onto pods (repro.distributed).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import partition as partition_mod
from repro.core.graph import build_subgraph, find_medoid, graph_stats
from repro.core.kmeans import kmeans_fit
from repro.core.merge import SubGraph, agglomerative_schedule, merge_pair, overlap_counts
from repro.core.partition import (
    PartitionConfig,
    assign_chunk,
    estimate_num_partitions,
)
from repro.core.pq import PQCodebook, pq_encode, pq_train
from repro.core.scheduler import (
    ClusterScheduler,
    ScheduledTask,
    fit_linear_cost,
    predict_build_cost,
)
from repro.core.search import beam_search

__all__ = ["SOGAICConfig", "SOGAICBuilder", "SOGAICIndex", "BuildReport"]


@dataclasses.dataclass(frozen=True)
class SOGAICConfig:
    """Full build configuration (partitioning ∪ graph ∪ cluster)."""

    # -- partitioning (paper symbols) --
    gamma: int = 4096  # Γ: max vectors per subset
    omega: int = 4  # Ω: max subsets per vector
    eps: float = 1.8  # ε: adaptive relaxation (paper's tuned value)
    k_cand: int = 32
    chunk_size: int = 8192
    n_repair: int = 2
    sample_size: int = 65536
    kmeans_iters: int = 25
    # -- graph --
    r: int = 32  # degree bound
    alpha: float = 1.2  # RobustPrune diversification
    knn_k: int | None = None
    rev_cap: int | None = None
    refine_rounds: int = 0  # Vamana-style beam re-search passes on the final graph
    # -- quantization --
    pq_m: int = 0  # 0 disables PQ
    pq_codes: int = 256
    pq_iters: int = 15
    # -- cluster --
    n_workers: int = 4
    straggler_factor: float = 3.0
    max_attempts: int = 4
    # -- misc --
    seed: int = 0
    ckpt_every_chunks: int = 16

    def partition_config(self) -> PartitionConfig:
        return PartitionConfig(
            gamma=self.gamma,
            omega=self.omega,
            eps=self.eps,
            k_cand=self.k_cand,
            chunk_size=self.chunk_size,
            n_repair=self.n_repair,
            sample_size=self.sample_size,
            kmeans_iters=self.kmeans_iters,
            seed=self.seed,
        )


@dataclasses.dataclass
class BuildReport:
    n: int = 0
    dim: int = 0
    phi: int = 0
    timings: dict = dataclasses.field(default_factory=dict)
    avg_overlap: float = 0.0
    fallback_count: int = 0
    build_makespan: float = 0.0
    merge_makespan: float = 0.0
    scheduler_events: int = 0
    graph: dict = dataclasses.field(default_factory=dict)
    cost_model: tuple[float, float] = (0.0, 1.0)

    def total_parallel_time(self) -> float:
        """Virtual wall time of the distributed phases plus host stages."""
        return (
            self.timings.get("centroids", 0.0)
            + self.timings.get("partition", 0.0)
            + self.build_makespan
            + self.merge_makespan
        )


class SOGAICIndex:
    """A built index: vectors + pruned graph + entry points (+ optional PQ).

    Search uses **centroid-routed entries**: each query enters the graph at
    the member nearest to its closest partition centroid (the centroids are
    a free by-product of the build).  A single medoid entry fails on
    cluster-structured data — greedy descent cannot escape a dense mega-
    cluster — while the graph itself is locally near-perfect; routing fixes
    exactly that (EXPERIMENTS.md §Paper-reproduction, isd3b row).
    """

    def __init__(
        self,
        x: np.ndarray,
        adj: np.ndarray,
        medoid: int,
        *,
        centroids: np.ndarray | None = None,
        entry_points: np.ndarray | None = None,
        pq_codebook: PQCodebook | None = None,
        pq_codes: np.ndarray | None = None,
    ) -> None:
        self.x = np.asarray(x)
        self.adj = np.asarray(adj)
        self.medoid = int(medoid)
        self.centroids = None if centroids is None else np.asarray(centroids)
        self.entry_points = None if entry_points is None else np.asarray(entry_points)
        self.pq_codebook = pq_codebook
        self.pq_codes = pq_codes
        self._x_dev = jnp.asarray(self.x, jnp.float32)
        self._adj_dev = jnp.asarray(self.adj)

    def _entries(self, queries: jax.Array):
        if self.centroids is None or self.entry_points is None:
            return jnp.int32(self.medoid)
        from repro.core.kmeans import pairwise_sq_l2

        d2 = pairwise_sq_l2(queries, jnp.asarray(self.centroids, jnp.float32))
        cid = jnp.argmin(d2, axis=1)
        return jnp.asarray(self.entry_points, jnp.int32)[cid]

    def search(
        self, queries: np.ndarray, k: int = 10, *, beam_l: int = 64, max_hops: int = 96
    ) -> tuple[np.ndarray, np.ndarray]:
        q = jnp.asarray(queries, jnp.float32)
        res = beam_search(
            self._x_dev,
            self._adj_dev,
            q,
            self._entries(q),
            k=k,
            beam_l=beam_l,
            max_hops=max_hops,
        )
        return np.asarray(res.ids), np.asarray(res.dists)

    def save(self, ckpt: CheckpointManager) -> None:
        arrays = {"x": self.x, "adj": self.adj, "medoid": np.int64(self.medoid)}
        if self.centroids is not None:
            arrays["centroids"] = self.centroids
            arrays["entry_points"] = self.entry_points
        if self.pq_codes is not None:
            arrays["pq_codes"] = self.pq_codes
            arrays["pq_codebooks"] = np.asarray(self.pq_codebook.codebooks)
        ckpt.save_arrays("index", **arrays)
        ckpt.mark_stage("index_saved")

    @classmethod
    def load(cls, ckpt: CheckpointManager) -> "SOGAICIndex":
        z = ckpt.load_arrays("index")
        pq_cb = (
            PQCodebook(codebooks=jnp.asarray(z["pq_codebooks"]))
            if "pq_codebooks" in z
            else None
        )
        return cls(
            z["x"],
            z["adj"],
            int(z["medoid"]),
            centroids=z.get("centroids"),
            entry_points=z.get("entry_points"),
            pq_codebook=pq_cb,
            pq_codes=z.get("pq_codes"),
        )


class SOGAICBuilder:
    """Checkpointed, fault-tolerant SOGAIC build."""

    def __init__(self, cfg: SOGAICConfig) -> None:
        self.cfg = cfg

    # -- stage 1 ------------------------------------------------------------
    def _stage_centroids(
        self, x: np.ndarray, phi: int, ckpt: CheckpointManager | None
    ) -> np.ndarray:
        if ckpt is not None and ckpt.stage_done("centroids"):
            return ckpt.load_array("centroids")
        key = jax.random.PRNGKey(self.cfg.seed)
        n = x.shape[0]
        sample_n = min(self.cfg.sample_size, n)
        skey, kkey = jax.random.split(key)
        sel = np.asarray(jax.random.choice(skey, n, (sample_n,), replace=False))
        sample = jnp.asarray(x[np.sort(sel)], jnp.float32)
        state = kmeans_fit(kkey, sample, phi, max_iters=self.cfg.kmeans_iters)
        centroids = np.asarray(state.centroids)
        if ckpt is not None:
            ckpt.save_array("centroids", centroids)
            ckpt.mark_stage("centroids", inertia=float(state.inertia))
        return centroids

    # -- stage 2 ------------------------------------------------------------
    def _stage_partition(
        self,
        x: np.ndarray,
        centroids: np.ndarray,
        codebook: PQCodebook | None,
        ckpt: CheckpointManager | None,
        progress: bool,
    ) -> tuple[partition_mod.PartitionResult, np.ndarray | None]:
        cfg = self.cfg
        n, d = x.shape
        phi = centroids.shape[0]
        start_chunk = 0
        sizes = np.zeros((phi,), np.int32)
        assign_idx = np.full((n, cfg.omega), -1, np.int32)
        codes = np.zeros((n, cfg.pq_m), np.uint8) if codebook is not None else None
        fallbacks = 0

        if ckpt is not None and ckpt.exists("partition_state"):
            st = ckpt.load_arrays("partition_state")
            start_chunk = int(st["next_chunk"])
            sizes = st["sizes"].astype(np.int32)
            assign_idx = st["assign_idx"]
            fallbacks = int(st["fallbacks"])
            if codes is not None and "codes" in st:
                codes = st["codes"]

        centroids_j = jnp.asarray(centroids, jnp.float32)
        n_chunks = -(-n // cfg.chunk_size)
        for ci in range(start_chunk, n_chunks):
            lo = ci * cfg.chunk_size
            hi = min(lo + cfg.chunk_size, n)
            xc = x[lo:hi]
            pad = 0
            if hi - lo < cfg.chunk_size and n > cfg.chunk_size:
                pad = cfg.chunk_size - (hi - lo)
                xc = np.concatenate([xc, np.zeros((pad, d), x.dtype)], axis=0)
            valid = np.ones((xc.shape[0],), bool)
            if pad:
                valid[hi - lo :] = False
            xc_dev = jnp.asarray(xc, jnp.float32)
            res = assign_chunk(
                xc_dev,
                centroids_j,
                jnp.asarray(sizes),
                jnp.asarray(valid),
                omega=cfg.omega,
                eps=cfg.eps,
                gamma=cfg.gamma,
                k_cand=cfg.k_cand,
                n_repair=cfg.n_repair,
            )
            # Fused PQ encode on the same device-resident chunk (Fig. 1c):
            if codebook is not None:
                chunk_codes = np.asarray(pq_encode(xc_dev, codebook))
                codes[lo:hi] = chunk_codes[: hi - lo]
            accept = np.asarray(res.accept)[: hi - lo]
            cand = np.asarray(res.cand_idx)[: hi - lo]
            unassigned = np.asarray(res.unassigned)[: hi - lo]
            for b in range(hi - lo):
                row = cand[b][accept[b]][: cfg.omega]
                assign_idx[lo + b, : len(row)] = row
                sizes[row] += 1
                if unassigned[b]:
                    j = partition_mod._host_fallback(
                        x[lo + b].astype(np.float64), centroids, sizes, cfg.gamma
                    )
                    assign_idx[lo + b, 0] = j
                    sizes[j] += 1
                    fallbacks += 1
            if ckpt is not None and (ci + 1) % cfg.ckpt_every_chunks == 0:
                state = dict(
                    next_chunk=np.int64(ci + 1),
                    sizes=sizes,
                    assign_idx=assign_idx,
                    fallbacks=np.int64(fallbacks),
                )
                if codes is not None:
                    state["codes"] = codes
                ckpt.save_arrays("partition_state", **state)
            if progress:  # pragma: no cover
                print(f"partition chunk {ci + 1}/{n_chunks} max_size={sizes.max()}")

        valid_cnt = (assign_idx >= 0).sum(axis=1)
        result = partition_mod.PartitionResult(
            assign_idx=assign_idx,
            sizes=sizes.astype(np.int64),
            avg_overlap=float(valid_cnt.mean()),
            fallback_count=fallbacks,
        )
        if ckpt is not None:
            ckpt.save_arrays(
                "partition_result", assign_idx=assign_idx, sizes=result.sizes
            )
            ckpt.mark_stage(
                "partition",
                avg_overlap=result.avg_overlap,
                fallbacks=fallbacks,
            )
            if codes is not None:
                ckpt.save_array("pq_codes", codes)
        return result, codes

    # -- stage 3 ------------------------------------------------------------
    def _stage_build(
        self,
        x: np.ndarray,
        members: list[np.ndarray],
        ckpt: CheckpointManager | None,
        runner: Callable | None,
        runner_wrapper: Callable | None = None,
    ) -> tuple[dict[int, SubGraph], dict]:
        cfg = self.cfg
        d = x.shape[1]
        subgraphs: dict[int, SubGraph] = {}
        done: set[int] = set()
        if ckpt is not None:
            for i in range(len(members)):
                if ckpt.exists(f"subgraph_{i}"):
                    z = ckpt.load_arrays(f"subgraph_{i}")
                    subgraphs[i] = SubGraph(ids=z["ids"], adj=z["adj"])
                    done.add(i)

        measured_sizes: list[int] = []
        measured_times: list[float] = []

        def default_runner(task: ScheduledTask, worker_id: int) -> float:
            ids = task.payload
            t0 = time.perf_counter()
            sub_x = x[ids].astype(np.float32)
            n_real = sub_x.shape[0]
            # Bucket to the next power of two so distinct subset sizes reuse
            # one compiled build (pads live at a far-away sentinel and are
            # masked out of the graph via n_valid).
            n_pad = max(64, 1 << (n_real - 1).bit_length())
            if n_pad > n_real:
                sentinel = float(np.abs(sub_x).max()) * 4.0 + 1e4
                pads = np.full((n_pad - n_real, sub_x.shape[1]), sentinel, np.float32)
                pads += np.arange(n_pad - n_real, dtype=np.float32)[:, None]
                sub_x = np.concatenate([sub_x, pads], axis=0)
            adj = build_subgraph(
                jnp.asarray(sub_x),
                cfg.r,
                alpha=cfg.alpha,
                knn_k=cfg.knn_k,
                rev_cap=cfg.rev_cap,
                n_valid=n_real,
            )
            adj.block_until_ready()
            dt = time.perf_counter() - t0
            sg = SubGraph(ids=ids.astype(np.int64), adj=np.asarray(adj)[:n_real])
            subgraphs[task.task_id] = sg
            if ckpt is not None:
                ckpt.save_arrays(f"subgraph_{task.task_id}", ids=sg.ids, adj=sg.adj)
            measured_sizes.append(len(ids))
            measured_times.append(dt)
            return dt

        run = runner or default_runner
        if runner_wrapper is not None:
            run = runner_wrapper(run)
        tasks = [
            ScheduledTask(
                task_id=i,
                cost=predict_build_cost(len(members[i]), d),
                payload=members[i],
            )
            for i in range(len(members))
            if i not in done
        ]
        sched = ClusterScheduler(
            cfg.n_workers,
            straggler_factor=cfg.straggler_factor,
            max_attempts=cfg.max_attempts,
        )
        stats = sched.run(tasks, run) if tasks else {"makespan": 0.0, "events": []}
        if measured_sizes:
            stats["cost_model"] = fit_linear_cost(
                np.array(measured_sizes), np.array(measured_times)
            )
        if ckpt is not None:
            ckpt.mark_stage("build", makespan=stats["makespan"])
        return subgraphs, stats

    # -- stage 4 ------------------------------------------------------------
    def _stage_merge(
        self,
        x: np.ndarray,
        subgraphs: dict[int, SubGraph],
        members: list[np.ndarray],
        ckpt: CheckpointManager | None,
    ) -> tuple[SubGraph, dict]:
        cfg = self.cfg
        k = len(members)
        if k == 1:
            return subgraphs[0], {"makespan": 0.0, "rounds": 0}
        sizes = np.array([len(m) for m in members])
        ov = overlap_counts(members)
        rounds = agglomerative_schedule(sizes, ov)

        graphs: dict[int, SubGraph] = dict(subgraphs)
        next_id = k
        total_makespan = 0.0
        for rnd_i, rnd in enumerate(rounds):
            pair_ids = list(range(next_id, next_id + len(rnd)))
            if ckpt is not None and all(
                ckpt.exists(f"merged_{mid}") for mid in pair_ids
            ):
                for mid in pair_ids:
                    z = ckpt.load_arrays(f"merged_{mid}")
                    graphs[mid] = SubGraph(ids=z["ids"], adj=z["adj"])
                next_id += len(rnd)
                continue

            def merge_runner(task: ScheduledTask, worker_id: int) -> float:
                a, b, mid = task.payload
                t0 = time.perf_counter()
                g = merge_pair(graphs[a], graphs[b], x, alpha=cfg.alpha)
                graphs[mid] = g
                if ckpt is not None:
                    ckpt.save_arrays(f"merged_{mid}", ids=g.ids, adj=g.adj)
                return time.perf_counter() - t0

            tasks = []
            for (a, b), mid in zip(rnd, pair_ids):
                est = graphs[a].n + graphs[b].n
                prio = len(np.intersect1d(graphs[a].ids, graphs[b].ids))
                tasks.append(
                    ScheduledTask(
                        task_id=mid, cost=float(est), priority=float(prio), payload=(a, b, mid)
                    )
                )
            sched = ClusterScheduler(cfg.n_workers, max_attempts=cfg.max_attempts)
            st = sched.run(tasks, merge_runner)
            total_makespan += st["makespan"]
            next_id += len(rnd)
            if ckpt is not None:
                ckpt.mark_stage(f"merge_round_{rnd_i}")

        final = graphs[next_id - 1]
        if ckpt is not None:
            ckpt.mark_stage("merge", makespan=total_makespan)
        return final, {"makespan": total_makespan, "rounds": len(rounds)}

    # -- driver ---------------------------------------------------------------
    def build(
        self,
        x: np.ndarray,
        *,
        ckpt: CheckpointManager | None = None,
        runner: Callable | None = None,
        runner_wrapper: Callable | None = None,
        progress: bool = False,
    ) -> tuple[SOGAICIndex, BuildReport]:
        """Build the index.  ``runner_wrapper`` (e.g.
        ``SimulatedCluster.wrap``) wraps the default build runner with
        failure/straggler injection — the scheduler's fault tolerance
        handles whatever it throws."""
        cfg = self.cfg
        n, d = x.shape
        phi = estimate_num_partitions(n, cfg.gamma, cfg.omega)
        report = BuildReport(n=n, dim=d, phi=phi)

        t0 = time.perf_counter()
        centroids = self._stage_centroids(x, phi, ckpt)
        report.timings["centroids"] = time.perf_counter() - t0

        codebook = None
        if cfg.pq_m > 0:
            t0 = time.perf_counter()
            if ckpt is not None and ckpt.exists("pq_codebooks"):
                codebook = PQCodebook(
                    codebooks=jnp.asarray(ckpt.load_array("pq_codebooks"))
                )
            else:
                sample_n = min(cfg.sample_size, n)
                key = jax.random.PRNGKey(cfg.seed + 7)
                sel = np.asarray(jax.random.choice(key, n, (sample_n,), replace=False))
                codebook = pq_train(
                    jax.random.PRNGKey(cfg.seed + 13),
                    jnp.asarray(x[sel], jnp.float32),
                    cfg.pq_m,
                    n_codes=cfg.pq_codes,
                    iters=cfg.pq_iters,
                )
                if ckpt is not None:
                    ckpt.save_array("pq_codebooks", np.asarray(codebook.codebooks))
            report.timings["pq_train"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        part, codes = self._stage_partition(x, centroids, codebook, ckpt, progress)
        report.timings["partition"] = time.perf_counter() - t0
        report.avg_overlap = part.avg_overlap
        report.fallback_count = part.fallback_count

        members = part.all_members()
        members = [m for m in members if len(m) > 0]
        t0 = time.perf_counter()
        subgraphs, build_stats = self._stage_build(
            x, members, ckpt, runner, runner_wrapper
        )
        report.timings["build"] = time.perf_counter() - t0
        report.build_makespan = build_stats["makespan"]
        report.cost_model = build_stats.get("cost_model", (0.0, 1.0))
        report.scheduler_events = len(build_stats.get("events", []))

        t0 = time.perf_counter()
        final, merge_stats = self._stage_merge(x, subgraphs, members, ckpt)
        report.timings["merge"] = time.perf_counter() - t0
        report.merge_makespan = merge_stats["makespan"]

        assert final.n == n, f"final graph covers {final.n}/{n} vectors"
        if cfg.refine_rounds > 0:
            from repro.core.graph import vamana_refine

            t0 = time.perf_counter()
            refined = vamana_refine(
                jnp.asarray(x, jnp.float32), jnp.asarray(final.adj), cfg.r,
                alpha=cfg.alpha, rounds=cfg.refine_rounds,
            )
            final = SubGraph(ids=final.ids, adj=np.asarray(refined))
            report.timings["refine"] = time.perf_counter() - t0
        medoid = int(find_medoid(jnp.asarray(x, jnp.float32)))
        # per-centroid entry points: the member nearest each partition
        # centroid (centroid-routed search entries)
        from repro.core.kmeans import pairwise_sq_l2 as _psl

        d2c = np.asarray(
            _psl(jnp.asarray(centroids, jnp.float32), jnp.asarray(x, jnp.float32))
        )  # (Φ, N)
        entry_points = np.argmin(d2c, axis=1).astype(np.int64)
        # final.ids is sorted == arange(n); local indices are global
        index = SOGAICIndex(
            x, final.adj, medoid,
            centroids=centroids, entry_points=entry_points,
            pq_codebook=codebook, pq_codes=codes,
        )
        report.graph = graph_stats(final.adj)
        if ckpt is not None:
            index.save(ckpt)
            ckpt.mark_stage("finalize")
        return index, report
