"""Agglomerative subgraph merging (paper §2.2, Fig. 2b).

Completed subgraphs are merged pairwise up a binary tree — O(log n) merge
depth instead of DiskANN's sequential single-machine O(n) on-disk merge.
The computationally intensive part is neighbor re-selection in the overlap
regions; disjoint adjacency carries over untouched.  Merging is in-memory
with direct access to the vectors, so re-pruning uses exact distances
("more precise pruning and selection" — the paper's quality argument).

Host code orchestrates id bookkeeping (NumPy); all distance/prune compute
is the jitted :func:`repro.core.graph.prune_candidate_lists`.

Scheduling hooks: :func:`agglomerative_schedule` pairs subgraphs with the
highest overlap first (the paper's "merges with higher overlap receive
higher priority") and emits per-round task lists the cluster scheduler
executes.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.graph import prune_candidate_lists

__all__ = ["SubGraph", "merge_pair", "overlap_counts", "agglomerative_schedule"]


@dataclasses.dataclass
class SubGraph:
    """A subgraph over a subset of the global vector set.

    ids  (n,)   int64 — sorted global vector ids of the members
    adj  (n, R) int32 — local adjacency (indices into ``ids``), -1 padded
    """

    ids: np.ndarray
    adj: np.ndarray

    def __post_init__(self) -> None:
        assert self.ids.ndim == 1 and self.adj.ndim == 2
        assert self.adj.shape[0] == self.ids.shape[0]

    @property
    def n(self) -> int:
        return int(self.ids.shape[0])

    @property
    def r(self) -> int:
        return int(self.adj.shape[1])

    def to_global(self) -> np.ndarray:
        """Adjacency in global ids (-1 padded)."""
        out = np.where(self.adj >= 0, self.ids[np.maximum(self.adj, 0)], -1)
        return out.astype(np.int64)


def overlap_counts(members: list[np.ndarray]) -> np.ndarray:
    """Pairwise |Sᵢ ∩ Sⱼ| for the subset member lists (sorted id arrays)."""
    k = len(members)
    out = np.zeros((k, k), np.int64)
    for i in range(k):
        for j in range(i + 1, k):
            c = len(np.intersect1d(members[i], members[j], assume_unique=True))
            out[i, j] = out[j, i] = c
    return out


def agglomerative_schedule(
    sizes: np.ndarray, overlaps: np.ndarray
) -> list[list[tuple[int, int]]]:
    """Binary merge tree as greedy max-overlap matching per round.

    Node labels: 0..k-1 are leaves; each merge (i, j) at global step t
    creates node k+t.  Returns rounds of (i, j) pairs; a leftover odd node
    carries into the next round.  Pairs within a round are ordered by
    overlap descending (higher-overlap merges scheduled first).
    """
    k = len(sizes)
    if k == 1:
        return []
    alive = list(range(k))
    sizes = {i: int(sizes[i]) for i in range(k)}
    ov = {}
    for i in range(k):
        for j in range(i + 1, k):
            ov[(i, j)] = int(overlaps[i, j])

    def get_ov(a, b):
        return ov.get((min(a, b), max(a, b)), 0)

    rounds: list[list[tuple[int, int]]] = []
    next_id = k
    while len(alive) > 1:
        pairs = sorted(
            [(a, b) for ai, a in enumerate(alive) for b in alive[ai + 1 :]],
            key=lambda p: (-get_ov(*p), sizes[p[0]] + sizes[p[1]]),
        )
        used: set[int] = set()
        round_pairs: list[tuple[int, int]] = []
        new_nodes: list[int] = []
        for a, b in pairs:
            if a in used or b in used:
                continue
            used.update((a, b))
            round_pairs.append((a, b))
            # conservative size/overlap estimates for the merged node
            sizes[next_id] = sizes[a] + sizes[b] - get_ov(a, b)
            for c in alive:
                if c not in (a, b):
                    ov[(min(c, next_id), max(c, next_id))] = get_ov(a, c) + get_ov(b, c)
            new_nodes.append(next_id)
            next_id += 1
        alive = [x for x in alive if x not in used] + new_nodes
        rounds.append(round_pairs)
    return rounds


def merge_pair(
    ga: SubGraph,
    gb: SubGraph,
    x_global,
    *,
    alpha: float = 1.2,
    backlink: bool = True,
) -> SubGraph:
    """Merge two subgraphs into one over the union of their members.

    - union ids; remap both adjacency tables into union-local indices
    - nodes present in exactly one side: adjacency carried over unchanged
    - overlap nodes: candidates = union of both neighbor lists → exact
      distances → RobustPrune to R
    - optional backlink stitch: overlap nodes are offered as candidates to
      their selected neighbors (keeps the two halves mutually reachable
      even where overlap is thin)

    ``x_global``: (N, d) global vector store (np.ndarray or jax.Array);
    rows are gathered for the union only.
    """
    r = max(ga.r, gb.r)
    union = np.union1d(ga.ids, gb.ids)
    pos_a = np.searchsorted(union, ga.ids)
    pos_b = np.searchsorted(union, gb.ids)
    m = len(union)

    in_a = np.zeros(m, bool)
    in_a[pos_a] = True
    in_b = np.zeros(m, bool)
    in_b[pos_b] = True
    both = in_a & in_b

    def remap(g: SubGraph, pos: np.ndarray) -> np.ndarray:
        out = np.full((g.n, r), -1, np.int32)
        valid = g.adj >= 0
        out[:, : g.r][valid] = pos[g.adj[valid]].astype(np.int32)
        return out

    adj_a = remap(ga, pos_a)  # rows indexed like ga, values in union-local
    adj_b = remap(gb, pos_b)

    new_adj = np.full((m, r), -1, np.int32)
    only_a = in_a & ~both
    only_b = in_b & ~both
    # carry-over rows (disjoint part, no recomputation — paper §2.2)
    a_rows = {int(p): i for i, p in enumerate(pos_a)}
    b_rows = {int(p): i for i, p in enumerate(pos_b)}
    idx_only_a = np.nonzero(only_a)[0]
    new_adj[idx_only_a] = adj_a[[a_rows[int(u)] for u in idx_only_a]]
    idx_only_b = np.nonzero(only_b)[0]
    new_adj[idx_only_b] = adj_b[[b_rows[int(u)] for u in idx_only_b]]

    # overlap rows: candidate union → exact-distance RobustPrune
    idx_both = np.nonzero(both)[0]
    if len(idx_both):
        cand = np.concatenate(
            [
                adj_a[[a_rows[int(u)] for u in idx_both]],
                adj_b[[b_rows[int(u)] for u in idx_both]],
            ],
            axis=1,
        )  # (o, 2R) union-local indices
        xu = np.asarray(x_global)[union].astype(np.float32)  # gather union once
        # bucket the vector table to a power of two so merge sizes share
        # compiled prunes (pad rows are never indexed — all ids < m)
        m_pad = 1 << (m - 1).bit_length()
        if m_pad > m:
            xu = np.concatenate([xu, np.zeros((m_pad - m, xu.shape[1]), np.float32)])
        xu_dev = jnp.asarray(xu)
        pruned = prune_candidate_lists(
            xu_dev,
            jnp.asarray(idx_both.astype(np.int32)),
            jnp.asarray(cand.astype(np.int32)),
            r,
            alpha=alpha,
            block=256,
        )
        new_adj[idx_both] = np.asarray(pruned)

        if backlink:
            # offer each overlap node as a candidate to its selected
            # neighbors that live in the disjoint parts
            sel = np.asarray(pruned)
            src = np.repeat(idx_both, sel.shape[1])
            dst = sel.reshape(-1)
            ok = dst >= 0
            src, dst = src[ok], dst[ok]
            targets, inv = np.unique(dst, return_inverse=True)
            cap = min(r, 16)
            extra = np.full((len(targets), cap), -1, np.int32)
            fill = np.zeros(len(targets), np.int32)
            for s, t in zip(src, inv):
                if fill[t] < cap:
                    extra[t, fill[t]] = s
                    fill[t] += 1
            cand2 = np.concatenate([new_adj[targets], extra], axis=1)
            pruned2 = prune_candidate_lists(
                xu_dev,
                jnp.asarray(targets.astype(np.int32)),
                jnp.asarray(cand2.astype(np.int32)),
                r,
                alpha=alpha,
                block=256,
            )
            new_adj[targets] = np.asarray(pruned2)

    return SubGraph(ids=union.astype(np.int64), adj=new_adj)
