"""Batched best-first (beam) search over an ANNS graph.

Jittable, fixed-shape reformulation of the classic GreedySearch used by
Vamana/DiskANN: a beam of ``L`` (id, dist, expanded) entries, one expansion
per step, candidate merge via a two-key sort dedup (no hash sets on TPU).
vmapped over a query batch — this is both the serving path and the
candidate generator for the optional Vamana refinement rounds.

Early exit: ``lax.while_loop`` over steps, stopping when the beam holds no
unexpanded candidate (vmap turns this into an any-lane-active loop).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SearchResult", "beam_search", "beam_search_single", "recall_at_k", "brute_force_topk"]


class SearchResult(NamedTuple):
    ids: jax.Array  # (q, k) int32 — nearest ids, ascending distance
    dists: jax.Array  # (q, k) float32 — L2 distances
    visited: jax.Array  # (q, V) int32 — expansion history (-1 pad)
    n_hops: jax.Array  # (q,) int32 — expansions performed


def _merge_dedup(ids, dists, expanded, beam_l):
    """Sort by (id, expanded-first), drop duplicate ids, sort by distance.

    The expanded copy of a node must survive dedup (its flag is the search
    state); encoding ``key = id·2 + (1 − expanded)`` makes it sort first
    among equal ids.
    """
    # Two stable sorts = lexicographic (id asc, expanded first) without any
    # widening: sort by the secondary key, then stably by the primary.
    order_a = jnp.argsort(1 - expanded.astype(jnp.int32), stable=True)
    ids_a, dists_a, exp_a = ids[order_a], dists[order_a], expanded[order_a]
    primary = jnp.where(ids_a >= 0, ids_a, jnp.iinfo(jnp.int32).max)
    order = jnp.argsort(primary, stable=True)
    ids_s = ids_a[order]
    dists_s = dists_a[order]
    exp_s = exp_a[order]
    dup = jnp.concatenate([jnp.zeros((1,), bool), ids_s[1:] == ids_s[:-1]])
    dists_s = jnp.where(dup | (ids_s < 0), jnp.inf, dists_s)
    order2 = jnp.argsort(dists_s)
    ids2 = jnp.where(jnp.isfinite(dists_s[order2]), ids_s[order2], -1)
    return ids2[:beam_l], dists_s[order2][:beam_l], exp_s[order2][:beam_l]


def beam_search_single(
    x: jax.Array,
    adj: jax.Array,
    query: jax.Array,
    entry: jax.Array,
    *,
    beam_l: int,
    max_hops: int,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Beam search for one query.  Returns (ids (L,), dists (L,), visited, hops)."""
    n, r = adj.shape
    q32 = query.astype(jnp.float32)

    d0 = jnp.sqrt(jnp.maximum(jnp.sum((x[entry].astype(jnp.float32) - q32) ** 2), 0.0))
    beam_ids = jnp.full((beam_l,), -1, jnp.int32).at[0].set(entry.astype(jnp.int32))
    beam_d = jnp.full((beam_l,), jnp.inf, jnp.float32).at[0].set(d0)
    beam_exp = jnp.zeros((beam_l,), bool)
    visited = jnp.full((max_hops,), -1, jnp.int32)

    def cond(state):
        _, beam_d, beam_exp, beam_ids, _, t = state
        frontier = (beam_ids >= 0) & ~beam_exp & jnp.isfinite(beam_d)
        return jnp.logical_and(t < max_hops, jnp.any(frontier))

    def body(state):
        beam_ids, beam_d, beam_exp, _, visited, t = state
        masked = jnp.where((beam_ids >= 0) & ~beam_exp, beam_d, jnp.inf)
        j = jnp.argmin(masked)
        node = beam_ids[j]
        beam_exp = beam_exp.at[j].set(True)
        visited = visited.at[t].set(node)
        nbrs = adj[jnp.maximum(node, 0)]
        nv = x[jnp.maximum(nbrs, 0)].astype(jnp.float32)
        nd = jnp.sqrt(jnp.maximum(jnp.sum((nv - q32[None, :]) ** 2, axis=-1), 0.0))
        nd = jnp.where(nbrs >= 0, nd, jnp.inf)
        all_ids = jnp.concatenate([beam_ids, nbrs])
        all_d = jnp.concatenate([beam_d, nd])
        all_exp = jnp.concatenate([beam_exp, jnp.zeros((r,), bool)])
        bi, bd, be = _merge_dedup(all_ids, all_d, all_exp, beam_l)
        return bi, bd, be, bi, visited, t + 1

    state = (beam_ids, beam_d, beam_exp, beam_ids, visited, jnp.int32(0))
    beam_ids, beam_d, beam_exp, _, visited, hops = jax.lax.while_loop(cond, body, state)
    return beam_ids, beam_d, visited, hops


@functools.partial(jax.jit, static_argnames=("k", "beam_l", "max_hops"))
def beam_search(
    x: jax.Array,
    adj: jax.Array,
    queries: jax.Array,
    entry: jax.Array,
    *,
    k: int = 10,
    beam_l: int = 64,
    max_hops: int = 96,
) -> SearchResult:
    """Batched beam search.  ``queries`` (q, d); ``entry`` is either a
    scalar (shared medoid) or a (q,) array of per-query entry points
    (centroid-routed entries — see SOGAICIndex.search)."""
    beam_l = max(beam_l, k)
    if jnp.ndim(entry) == 0:
        entry = jnp.broadcast_to(entry, (queries.shape[0],))

    def one(query, ent):
        ids, dists, visited, hops = beam_search_single(
            x, adj, query, ent, beam_l=beam_l, max_hops=max_hops
        )
        return ids[:k], dists[:k], visited, hops

    ids, dists, visited, hops = jax.vmap(one)(queries, entry)
    return SearchResult(ids=ids, dists=dists, visited=visited, n_hops=hops)


@functools.partial(jax.jit, static_argnames=("k",))
def brute_force_topk(x: jax.Array, queries: jax.Array, k: int):
    """Exact ground truth (q, k) for recall evaluation."""
    x = x.astype(jnp.float32)
    q = queries.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=-1)[None, :]
    q2 = jnp.sum(q * q, axis=-1)[:, None]
    d2 = jnp.maximum(q2 - 2.0 * (q @ x.T) + x2, 0.0)
    neg, idx = jax.lax.top_k(-d2, k)
    return jnp.sqrt(jnp.maximum(-neg, 0.0)), idx.astype(jnp.int32)


def recall_at_k(found_ids: np.ndarray, true_ids: np.ndarray) -> float:
    """Mean |found ∩ true| / k over the query batch."""
    found_ids = np.asarray(found_ids)
    true_ids = np.asarray(true_ids)
    q, k = true_ids.shape
    hits = 0
    for i in range(q):
        hits += len(set(found_ids[i].tolist()) & set(true_ids[i].tolist()))
    return hits / (q * k)
