"""SOGAIC core — the paper's primary contribution as composable JAX modules.

Layers (bottom-up):

  kmeans      Φ-centroid seeding on a dataset sample (mini/full-batch Lloyd)
  partition   Algorithm 1 — overload-aware adaptive vector assignment
              (exact sequential oracle + chunk-synchronous batched JAX)
  pq          product quantization (train / encode / ADC), fused into the
              partitioning chunk pipeline exactly once per vector
  graph       TPU-native subgraph construction: tiled exact kNN + RobustPrune
              (+ optional Vamana-style beam refinement)
  search      batched best-first beam search over a (sub)graph
  merge       agglomerative pairwise subgraph merging + overlap-priority tree
  scheduler   LPT load balancing, speculative re-execution, elastic workers
  pipeline    checkpointed end-to-end build orchestration (SOGAICBuilder)
"""

from repro.core.kmeans import kmeans_fit, kmeans_plus_plus_init, pairwise_sq_l2
from repro.core.partition import (
    PartitionConfig,
    assign_chunk,
    assign_reference,
    estimate_num_partitions,
)
from repro.core.pq import PQCodebook, adc_lookup_tables, pq_encode, pq_train
from repro.core.graph import (
    build_knn_graph,
    build_subgraph,
    find_medoid,
    robust_prune,
    vamana_refine,
)
from repro.core.search import beam_search, recall_at_k
from repro.core.merge import SubGraph, agglomerative_schedule, merge_pair
from repro.core.scheduler import ClusterScheduler, lpt_schedule
from repro.core.pipeline import SOGAICBuilder, SOGAICConfig, SOGAICIndex

__all__ = [
    "kmeans_fit",
    "kmeans_plus_plus_init",
    "pairwise_sq_l2",
    "PartitionConfig",
    "assign_chunk",
    "assign_reference",
    "estimate_num_partitions",
    "PQCodebook",
    "pq_train",
    "pq_encode",
    "adc_lookup_tables",
    "build_knn_graph",
    "build_subgraph",
    "robust_prune",
    "find_medoid",
    "vamana_refine",
    "beam_search",
    "recall_at_k",
    "SubGraph",
    "merge_pair",
    "agglomerative_schedule",
    "lpt_schedule",
    "ClusterScheduler",
    "SOGAICBuilder",
    "SOGAICConfig",
    "SOGAICIndex",
]
