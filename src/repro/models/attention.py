"""Attention variants: GQA (covers MHA/MQA) and DeepSeek-style MLA.

Design points:

  * **Query-chunked attention** for training/prefill: the (S, S) score
    matrix is never materialized — `lax.map` over query chunks computes
    (chunk, S) tiles with an exact per-row softmax.  Same memory shape a
    fused flash kernel produces; XLA fuses the inner ops well on TPU and
    the activation footprint drops from O(B·H·S²) to O(B·H·qc·S).
  * **MLA decode with the absorbed trick**: the KV cache stores only the
    compressed latent (kv_lora + rope dims); at decode the q→k projection
    is absorbed through W_UK so attention runs directly in latent space
    and W_UV is applied once to the attended latent — O(H·(lora+rope))
    per cached token instead of O(H·(nope+v)) — an ~(H·256)/(576)≈57×
    KV-cache reduction for the 128-head config.
  * Everything takes/returns plain arrays; the transformer supplies
    per-layer params (stacked under `lax.scan`).

Shapes: x (B, S, D); caches are per-layer slices handled by the scan.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, constrain, rms_norm

__all__ = [
    "gqa_attention",
    "gqa_decode",
    "mla_attention",
    "mla_decode",
]


def _chunked_softmax_attn(q, k, v, *, chunk: int, causal: bool, q_offset=0,
                          cfg=None, heads_tp=False):
    """q (B, Sq, H, dh), k (B, Sk, KV, dh), v (B, Sk, KV, dv) → (B, Sq, H, dv).

    H must be a multiple of KV (GQA groups).  Chunked over Sq.
    """
    b, sq, h, dh = q.shape
    _, sk, kv, _ = k.shape
    dv = v.shape[-1]
    g = h // kv
    scale = dh ** -0.5
    chunk = min(chunk, sq)
    sq_orig = sq
    if sq % chunk:  # pad queries to a whole number of chunks
        pad = chunk - sq % chunk
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        sq = q.shape[1]
    n_chunks = sq // chunk
    qc = q.reshape(b, n_chunks, chunk, kv, g, dh)
    qc = jnp.moveaxis(qc, 1, 0)  # (n_chunks, B, chunk, KV, g, dh)

    kpos = jnp.arange(sk)

    def one_chunk(args):
        qi, ci = args  # (B, chunk, KV, g, dh), scalar chunk idx
        scores = jnp.einsum(
            "bqkgh,bskh->bkgqs", qi.astype(jnp.float32), k.astype(jnp.float32)
        ) * scale  # (B, KV, g, chunk, Sk)
        if cfg is not None:
            scores = constrain(
                scores, cfg, "dp", "tp" if heads_tp else None, None, None, None
            )
        if causal:
            qpos = q_offset + ci * chunk + jnp.arange(chunk)
            mask = kpos[None, :] <= qpos[:, None]  # (chunk, Sk)
            scores = jnp.where(mask[None, None, None], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgqs,bskv->bqkgv", w, v.astype(jnp.float32))
        return out.reshape(b, chunk, h, dv)

    # flash-style remat: recompute per-chunk scores in backward instead of
    # saving stacked (n_chunks, B, H, chunk, S) residuals across the scan
    out = jax.lax.map(jax.checkpoint(one_chunk), (qc, jnp.arange(n_chunks)))
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq, h, dv)[:, :sq_orig]
    return out.astype(q.dtype)


def gqa_attention(x, lp, freqs, cfg, *, chunk=512):
    """Full-sequence causal GQA.  Returns (attn_out (B,S,D), (k, v)) —
    k/v returned for prefill cache capture."""
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = (x @ lp["wq"]).reshape(b, s, h, dh)
    k = (x @ lp["wk"]).reshape(b, s, kv, dh)
    v = (x @ lp["wv"]).reshape(b, s, kv, dh)
    q = apply_rope(q, freqs)
    k = apply_rope(k, freqs)
    heads_tp = h % 16 == 0 and kv % 16 == 0
    # (S-sharded q under SP was tried and refuted — XLA reshards the chunk
    # loop and all-gather bytes INCREASE ~1.5×; see EXPERIMENTS.md §Perf.)
    q = constrain(q, cfg, "dp", None, "tp" if heads_tp else None, None)
    k = constrain(k, cfg, "dp", None, "tp" if heads_tp else None, None)
    v = constrain(v, cfg, "dp", None, "tp" if heads_tp else None, None)
    out = _chunked_softmax_attn(
        q, k, v, chunk=chunk, causal=True, cfg=cfg, heads_tp=heads_tp
    )
    return out.reshape(b, s, h * dh) @ lp["wo"], (k, v)


def gqa_decode(x, lp, cache_k, cache_v, pos, freqs_all, cfg):
    """One-token decode.  x (B, D); cache_k/v (B, Smax, KV, dh); pos scalar.

    Returns (out (B, D), new_cache_k, new_cache_v)."""
    b, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    g = h // kv
    q = (x @ lp["wq"]).reshape(b, 1, h, dh)
    k = (x @ lp["wk"]).reshape(b, 1, kv, dh)
    v = (x @ lp["wv"]).reshape(b, 1, kv, dh)
    fr = jax.lax.dynamic_slice_in_dim(freqs_all, pos, 1, axis=0)  # (1, dh/2, 2)
    q = apply_rope(q, fr)[:, 0]  # (B, H, dh)
    k = apply_rope(k, fr)[:, 0]  # (B, KV, dh)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k[:, None], pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, pos, axis=1)
    smax = cache_k.shape[1]
    qg = q.reshape(b, kv, g, dh)
    scores = jnp.einsum(
        "bkgh,bskh->bkgs", qg.astype(jnp.float32), cache_k.astype(jnp.float32)
    ) * (dh ** -0.5)
    mask = jnp.arange(smax)[None, None, None, :] <= pos
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bkgs,bskv->bkgv", w, cache_v.astype(jnp.float32))
    ctx = ctx.reshape(b, h * dh).astype(x.dtype)
    return ctx @ lp["wo"], cache_k, cache_v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): compressed-latent KV
# ---------------------------------------------------------------------------


def _mla_qkv(x, lp, freqs, cfg):
    """Shared projection path for MLA train/prefill.

    Returns q (B,S,H,nope+rope), k (B,S,H,nope+rope), v (B,S,H,v),
    latent_cache (B,S,lora+rope)."""
    b, s, d = x.shape
    h = cfg.n_heads
    nope, rope, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    lora = cfg.mla_kv_lora

    if cfg.mla_q_lora:
        ql = rms_norm(x @ lp["wq_a"], lp["q_norm"])
        q = (ql @ lp["wq_b"]).reshape(b, s, h, nope + rope)
    else:
        q = (x @ lp["wq"]).reshape(b, s, h, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, freqs)

    kv_a = x @ lp["wkv_a"]  # (B, S, lora + rope)
    latent, k_rope = kv_a[..., :lora], kv_a[..., lora:]
    latent = rms_norm(latent, lp["kv_norm"])
    k_rope = apply_rope(k_rope[:, :, None, :], freqs)  # (B,S,1,rope) shared
    kv = (latent @ lp["wkv_b"]).reshape(b, s, h, nope + dv)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, rope))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    cache = jnp.concatenate([latent, k_rope[:, :, 0, :]], axis=-1)
    return q, k, v, cache


def mla_attention(x, lp, freqs, cfg, *, chunk=512):
    """Full-sequence causal MLA (expanded form for train/prefill).

    Returns (out (B,S,D), latent_cache (B,S,lora+rope))."""
    b, s, d = x.shape
    h, dv = cfg.n_heads, cfg.v_head_dim
    q, k, v, cache = _mla_qkv(x, lp, freqs, cfg)
    heads_tp = h % 16 == 0
    q = constrain(q, cfg, "dp", None, "tp" if heads_tp else None, None)
    k = constrain(k, cfg, "dp", None, "tp" if heads_tp else None, None)
    v = constrain(v, cfg, "dp", None, "tp" if heads_tp else None, None)
    out = _chunked_softmax_attn(
        q, k, v, chunk=chunk, causal=True, cfg=cfg, heads_tp=heads_tp
    )
    return out.reshape(b, s, h * dv) @ lp["wo"], cache


def mla_decode(x, lp, cache, pos, freqs_all, cfg):
    """Absorbed-matmul MLA decode over the compressed latent cache.

    x (B, D); cache (B, Smax, lora+rope).  Returns (out (B,D), cache)."""
    b, d = x.shape
    h = cfg.n_heads
    nope, rope, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    lora = cfg.mla_kv_lora

    if cfg.mla_q_lora:
        ql = rms_norm(x @ lp["wq_a"], lp["q_norm"])
        q = (ql @ lp["wq_b"]).reshape(b, h, nope + rope)
    else:
        q = (x @ lp["wq"]).reshape(b, h, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    fr = jax.lax.dynamic_slice_in_dim(freqs_all, pos, 1, axis=0)
    q_rope = apply_rope(q_rope[:, None], fr)[:, 0]  # (B, H, rope)

    kv_a = x @ lp["wkv_a"]
    latent, k_rope = kv_a[..., :lora], kv_a[..., lora:]
    latent = rms_norm(latent, lp["kv_norm"])
    k_rope = apply_rope(k_rope[:, None, None, :], fr)[:, 0, 0]  # (B, rope)
    new_entry = jnp.concatenate([latent, k_rope], axis=-1)  # (B, lora+rope)
    cache = jax.lax.dynamic_update_slice_in_dim(
        cache, new_entry[:, None].astype(cache.dtype), pos, axis=1
    )

    # absorb W_UK:   q_lat[b,h,l] = Σ_n q_nope[b,h,n] · W_UK[l,h,n]
    wkv_b = lp["wkv_b"].reshape(lora, h, nope + dv)
    w_uk = wkv_b[..., :nope]  # (lora, H, nope)
    w_uv = wkv_b[..., nope:]  # (lora, H, dv)
    q_lat = jnp.einsum("bhn,lhn->bhl", q_nope.astype(jnp.float32), w_uk.astype(jnp.float32))

    c_lat = cache[..., :lora].astype(jnp.float32)  # (B, Smax, lora)
    c_rope = cache[..., lora:].astype(jnp.float32)  # (B, Smax, rope)
    scale = (nope + rope) ** -0.5
    scores = (
        jnp.einsum("bhl,bsl->bhs", q_lat, c_lat)
        + jnp.einsum("bhr,bsr->bhs", q_rope.astype(jnp.float32), c_rope)
    ) * scale
    smax = cache.shape[1]
    mask = jnp.arange(smax)[None, None, :] <= pos
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    ctx_lat = jnp.einsum("bhs,bsl->bhl", w, c_lat)  # (B, H, lora)
    ctx = jnp.einsum("bhl,lhv->bhv", ctx_lat, w_uv.astype(jnp.float32))  # (B,H,dv)
    ctx = ctx.reshape(b, h * dv).astype(x.dtype)
    return ctx @ lp["wo"], cache
