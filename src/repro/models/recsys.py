"""RecSys models: FM, DeepFM, xDeepFM (CIN), two-tower retrieval.

All four share the sparse-embedding front-end (one stacked row-sharded
table, see repro.models.embedding) and differ in the interaction op:

  fm         pairwise ⟨vᵢ,vⱼ⟩xᵢxⱼ via the O(nk) sum-square trick (Rendle)
  deepfm     FM branch ∥ deep MLP, summed logits
  xdeepfm    CIN (outer-product feature maps compressed by 1×1 conv,
              sum-pooled per layer) ∥ deep MLP
  two_tower  user/item MLP towers → dot; in-batch sampled softmax with
              logQ-free uniform correction; retrieval = batched dot + top-k
              over a candidate embedding matrix (sharded over 'model')

Inputs: ``sparse_idx`` (B, F) global row ids (field offsets pre-added),
``dense`` (B, n_dense) floats, ``labels`` (B,) {0,1} for CTR models.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.models.layers import dense_init

__all__ = [
    "init_recsys_params",
    "recsys_logits",
    "recsys_loss",
    "two_tower_embed",
    "two_tower_loss",
    "retrieval_scores",
]


def _mlp_params(key, dims, dtype=jnp.float32):
    ks = jax.random.split(key, len(dims) - 1)
    return [
        {"w": dense_init(ks[i], (dims[i], dims[i + 1]), dtype=dtype),
         "b": jnp.zeros((dims[i + 1],), dtype)}
        for i in range(len(dims) - 1)
    ]


def _mlp_apply(layers, x, *, final_act=False):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i < len(layers) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def init_recsys_params(key, cfg: RecsysConfig) -> dict:
    ks = iter(jax.random.split(key, 16))
    v_total = cfg.total_vocab
    d = cfg.embed_dim
    params: dict = {
        "table": dense_init(next(ks), (v_total, d), scale=0.01),
        "linear": dense_init(next(ks), (v_total, 1), scale=0.01),
        "bias": jnp.zeros((1,)),
    }
    if cfg.model in ("deepfm", "xdeepfm"):
        in_dim = cfg.n_sparse * d + cfg.n_dense
        params["mlp"] = _mlp_params(next(ks), (in_dim, *cfg.mlp, 1))
    if cfg.model == "xdeepfm":
        cin = []
        h_prev = cfg.n_sparse
        for h_next in cfg.cin_layers:
            cin.append(dense_init(next(ks), (h_prev * cfg.n_sparse, h_next), scale=0.1))
            h_prev = h_next
        params["cin"] = cin
        params["cin_out"] = dense_init(next(ks), (sum(cfg.cin_layers), 1), scale=0.1)
    if cfg.model == "two_tower":
        d_in_user = cfg.n_sparse * d + cfg.n_dense
        params["user_mlp"] = _mlp_params(next(ks), (d_in_user, *cfg.tower_mlp))
        params["item_table"] = dense_init(next(ks), (cfg.n_items, d), scale=0.01)
        params["item_mlp"] = _mlp_params(next(ks), (d, *cfg.tower_mlp))
        params.pop("linear")
    return params


# ---------------------------------------------------------------------------
# CTR models (fm / deepfm / xdeepfm)
# ---------------------------------------------------------------------------


def _fm_interaction(emb: jax.Array) -> jax.Array:
    """emb (B, F, D) → (B,) — ½((Σ_f v)² − Σ_f v²) summed over D."""
    s = emb.sum(axis=1)
    s2 = (emb * emb).sum(axis=1)
    return 0.5 * (s * s - s2).sum(axis=-1)


def _cin(emb: jax.Array, cin_ws, cin_out) -> jax.Array:
    """Compressed Interaction Network.  emb (B, F, D) → (B,)."""
    x0 = emb
    xk = emb
    pooled = []
    for w in cin_ws:
        b, hk, d = xk.shape
        f = x0.shape[1]
        z = jnp.einsum("bhd,bfd->bhfd", xk, x0).reshape(b, hk * f, d)
        xk = jnp.einsum("bmd,mn->bnd", z, w)  # 1×1 conv compress
        pooled.append(xk.sum(axis=-1))  # (B, H_next)
    return (jnp.concatenate(pooled, axis=-1) @ cin_out)[:, 0]


def recsys_logits(params, cfg: RecsysConfig, sparse_idx, dense, *, lookup=None):
    """CTR logit (B,).  ``lookup(table, idx)`` overrides the gather (the
    launcher passes the row-sharded shard_map lookup)."""
    take = lookup if lookup is not None else (lambda t, i: jnp.take(t, i, axis=0))
    emb = take(params["table"], sparse_idx)  # (B, F, D)
    lin = take(params["linear"], sparse_idx)[..., 0]  # (B, F)
    logit = lin.sum(axis=-1) + params["bias"][0]
    if cfg.model in ("fm", "deepfm"):
        logit = logit + _fm_interaction(emb)
    if cfg.model == "xdeepfm":
        logit = logit + _cin(emb, params["cin"], params["cin_out"])
    if cfg.model in ("deepfm", "xdeepfm"):
        b = emb.shape[0]
        deep_in = jnp.concatenate([emb.reshape(b, -1), dense], axis=-1)
        logit = logit + _mlp_apply(params["mlp"], deep_in)[:, 0]
    return logit


def recsys_loss(params, cfg, sparse_idx, dense, labels, *, lookup=None):
    logit = recsys_logits(params, cfg, sparse_idx, dense, lookup=lookup)
    y = labels.astype(jnp.float32)
    loss = jnp.mean(
        jnp.maximum(logit, 0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit)))
    )
    return loss, {"bce": loss}


# ---------------------------------------------------------------------------
# two-tower retrieval
# ---------------------------------------------------------------------------


def two_tower_embed(params, cfg, sparse_idx, dense, *, lookup=None):
    """User-tower embedding (B, d_out), L2-normalized."""
    take = lookup if lookup is not None else (lambda t, i: jnp.take(t, i, axis=0))
    emb = take(params["table"], sparse_idx)  # (B, F, D)
    b = emb.shape[0]
    u = jnp.concatenate([emb.reshape(b, -1), dense], axis=-1)
    u = _mlp_apply(params["user_mlp"], u)
    return u / jnp.maximum(jnp.linalg.norm(u, axis=-1, keepdims=True), 1e-6)


def item_tower_embed(params, item_ids):
    it = jnp.take(params["item_table"], item_ids, axis=0)
    it = _mlp_apply(params["item_mlp"], it)
    return it / jnp.maximum(jnp.linalg.norm(it, axis=-1, keepdims=True), 1e-6)


def two_tower_loss(params, cfg, sparse_idx, dense, item_ids, *, lookup=None, tau=0.05):
    """In-batch sampled softmax (positives on the diagonal)."""
    u = two_tower_embed(params, cfg, sparse_idx, dense, lookup=lookup)  # (B, d)
    it = item_tower_embed(params, item_ids)  # (B, d)
    logits = (u @ it.T) / tau
    labels = jnp.arange(u.shape[0])
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(lse - gold)
    return loss, {"softmax": loss}


def build_retrieval_index(params, cfg, *, sogaic_cfg=None, n_items=None):
    """Build a SOGAIC ANN index over the item tower's embeddings — the
    direct application of the paper's technique to this architecture
    (DESIGN.md §5): the candidate corpus a production retrieval stack
    serves is exactly what SOGAIC's construction pipeline indexes.

    Returns a SOGAICIndex whose `search(query_emb)` replaces the
    brute-force `retrieval_scores` at sub-linear cost.
    """
    import numpy as np

    from repro.core.pipeline import SOGAICBuilder, SOGAICConfig

    n = n_items if n_items is not None else params["item_table"].shape[0]
    item_emb = np.asarray(item_tower_embed(params, jnp.arange(n)))
    if sogaic_cfg is None:
        sogaic_cfg = SOGAICConfig(
            gamma=max(64, n // 4), omega=3, eps=1.8,
            r=min(24, max(8, n // 16)),
            sample_size=min(4096, n), chunk_size=min(2048, n), n_workers=4,
        )
    index, report = SOGAICBuilder(sogaic_cfg).build(item_emb)
    return index, report


def retrieval_scores(query_emb, cand_emb, k: int = 100):
    """Score 1..B queries against N candidates; top-k.  cand_emb rows are
    'model'-sharded at the launcher level (local top-k + gather merge is
    XLA's job under GSPMD; the shard_map variant lives in
    repro.distributed.steps.make_knn_step for the SOGAIC path)."""
    scores = query_emb @ cand_emb.T  # (B, N)
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx.astype(jnp.int32)
