"""Row-sharded embedding tables: partitioned lookup + psum.

JAX has no native EmbeddingBag and no row-sharded gather primitive, so the
system implements it (per the assignment, this IS part of the system):

  * the table is one stacked (ΣV, dim) array, row-sharded over the
    ``model`` mesh axis (the only axis that can hold 10⁸–10⁹-row tables);
  * lookup inside shard_map: each shard gathers the rows it owns (masked
    local take), then one psum over ``model`` reconstitutes the batch —
    the collective moves (B, F, dim) activation bytes, never table bytes;
  * multi-hot bags reduce with ``segment_sum`` before the psum (bag-sum
    happens shard-local — EmbeddingBag semantics).

On a single device (smoke tests) the plain ``jnp.take`` path is used.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["field_offsets", "embedding_lookup", "make_sharded_lookup", "embedding_bag"]


def field_offsets(vocab_sizes) -> np.ndarray:
    """Cumulative row offsets of each field inside the stacked table."""
    return np.concatenate([[0], np.cumsum(np.asarray(vocab_sizes))[:-1]]).astype(
        np.int64
    )


def embedding_lookup(table: jax.Array, idx: jax.Array) -> jax.Array:
    """Single-device lookup: idx (..., ) global row ids → (..., dim)."""
    return jnp.take(table, idx, axis=0)


def make_sharded_lookup(mesh: Mesh):
    """Returns lookup(table, idx) with the table row-sharded over 'model'.

    table (V, dim) P('model', None); idx (B, F) P(dp, None);
    out (B, F, dim) P(dp, None, None).
    """
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def body(table_loc, idx_loc):
        v_loc = table_loc.shape[0]
        mi = jax.lax.axis_index("model")
        rel = idx_loc.astype(jnp.int32) - (mi * v_loc).astype(jnp.int32)
        ok = (rel >= 0) & (rel < v_loc)
        safe = jnp.clip(rel, 0, v_loc - 1)
        vals = jnp.take(table_loc, safe, axis=0)  # (B, F, dim)
        vals = jnp.where(ok[..., None], vals, 0.0)
        return jax.lax.psum(vals, "model")

    return jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(P("model", None), P(dp, None)),
        out_specs=P(dp, None, None),
        check_vma=False,
    )


def embedding_bag(
    table: jax.Array,
    bag_idx: jax.Array,
    bag_segments: jax.Array,
    n_bags: int,
    *,
    mode: str = "sum",
) -> jax.Array:
    """EmbeddingBag: ragged multi-hot reduce.

    bag_idx (NNZ,) row ids; bag_segments (NNZ,) bag assignment (sorted);
    returns (n_bags, dim).  ``jnp.take`` + ``segment_sum`` — the canonical
    JAX formulation of torch.nn.EmbeddingBag.
    """
    vals = jnp.take(table, jnp.maximum(bag_idx, 0), axis=0)
    vals = jnp.where((bag_idx >= 0)[:, None], vals, 0.0)
    out = jax.ops.segment_sum(vals, jnp.maximum(bag_segments, 0), num_segments=n_bags)
    if mode == "mean":
        cnt = jax.ops.segment_sum(
            (bag_idx >= 0).astype(jnp.float32), jnp.maximum(bag_segments, 0),
            num_segments=n_bags,
        )
        out = out / jnp.maximum(cnt[:, None], 1.0)
    return out
