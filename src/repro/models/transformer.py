"""Config-driven transformer LM: dense/GQA, MLA, MoE — train/prefill/decode.

Layer stacking via ``lax.scan`` over (L, ...)-stacked params (one compiled
layer body regardless of depth; optional ``jax.checkpoint`` remat).  All
functions are pure; sharding is carried by the PartitionSpec pytrees from
:func:`lm_param_specs` / :func:`lm_cache_specs` and applied by the
launcher's jit in/out shardings (GSPMD propagates through the scan).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import LMConfig
from repro.models.attention import (
    gqa_attention,
    gqa_decode,
    mla_attention,
    mla_decode,
)
from repro.models.layers import constrain, dense_init, rms_norm, rope_freqs, swiglu
from repro.models.moe import moe_ffn

__all__ = [
    "init_lm_params",
    "lm_hidden",
    "lm_param_specs",
    "lm_cache_shape",
    "lm_cache_spec",
    "lm_forward",
    "lm_loss",
    "lm_prefill",
    "lm_decode_step",
]


def _dtype(cfg: LMConfig):
    return jnp.dtype(cfg.dtype)


def _rope_dim(cfg: LMConfig) -> int:
    return cfg.qk_rope_dim if cfg.attn == "mla" else cfg.d_head


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_lm_params(key: jax.Array, cfg: LMConfig) -> dict:
    l, d, h, kv, dh = cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dt = _dtype(cfg)
    keys = iter(jax.random.split(key, 64))

    layers: dict[str, jax.Array] = {
        "attn_norm": jnp.ones((l, d), dt),
        "ffn_norm": jnp.ones((l, d), dt),
    }
    if cfg.attn == "mla":
        nope, rope, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
        lora = cfg.mla_kv_lora
        if cfg.mla_q_lora:
            layers["wq_a"] = dense_init(next(keys), (l, d, cfg.mla_q_lora), dtype=dt)
            layers["q_norm"] = jnp.ones((l, cfg.mla_q_lora), dt)
            layers["wq_b"] = dense_init(
                next(keys), (l, cfg.mla_q_lora, h * (nope + rope)), dtype=dt
            )
        else:
            layers["wq"] = dense_init(next(keys), (l, d, h * (nope + rope)), dtype=dt)
        layers["wkv_a"] = dense_init(next(keys), (l, d, lora + rope), dtype=dt)
        layers["kv_norm"] = jnp.ones((l, lora), dt)
        layers["wkv_b"] = dense_init(next(keys), (l, lora, h * (nope + dv)), dtype=dt)
        layers["wo"] = dense_init(next(keys), (l, h * dv, d), dtype=dt)
    else:
        layers["wq"] = dense_init(next(keys), (l, d, h * dh), dtype=dt)
        layers["wk"] = dense_init(next(keys), (l, d, kv * dh), dtype=dt)
        layers["wv"] = dense_init(next(keys), (l, d, kv * dh), dtype=dt)
        layers["wo"] = dense_init(next(keys), (l, h * dh, d), dtype=dt)

    if cfg.moe is not None:
        e, fe = cfg.moe.n_experts, cfg.moe.d_ff_expert
        layers["router"] = dense_init(next(keys), (l, d, e), dtype=jnp.float32)
        layers["we_gate"] = dense_init(next(keys), (l, e, d, fe), dtype=dt)
        layers["we_up"] = dense_init(next(keys), (l, e, d, fe), dtype=dt)
        layers["we_down"] = dense_init(next(keys), (l, e, fe, d), dtype=dt)
        if cfg.moe.n_shared:
            fs = cfg.moe.n_shared * fe
            layers["ws_gate"] = dense_init(next(keys), (l, d, fs), dtype=dt)
            layers["ws_up"] = dense_init(next(keys), (l, d, fs), dtype=dt)
            layers["ws_down"] = dense_init(next(keys), (l, fs, d), dtype=dt)
    else:
        layers["w_gate"] = dense_init(next(keys), (l, d, cfg.d_ff), dtype=dt)
        layers["w_up"] = dense_init(next(keys), (l, d, cfg.d_ff), dtype=dt)
        layers["w_down"] = dense_init(next(keys), (l, cfg.d_ff, d), dtype=dt)

    return {
        "embed": dense_init(next(keys), (cfg.vocab, d), scale=0.02, dtype=dt),
        "layers": layers,
        "final_norm": jnp.ones((d,), dt),
        "lm_head": dense_init(next(keys), (d, cfg.vocab), dtype=dt),
    }


# ---------------------------------------------------------------------------
# sharding specs
# ---------------------------------------------------------------------------


def lm_param_specs(cfg: LMConfig, mesh_axes: tuple[str, ...]) -> dict:
    """PartitionSpec pytree matching init_lm_params.

    TP over 'model' where dims divide; FSDP (ZeRO-3-style) over the batch
    axes ('pod','data') on a complementary dim.  Attention projections fall
    back to FSDP-only when head counts don't divide TP (llama3.2/smollm).
    """
    fsdp = tuple(a for a in ("pod", "data") if a in mesh_axes)
    tp = "model" if "model" in mesh_axes else None
    # guard: without a model axis everything TP-ish becomes None
    l = cfg.n_layers

    def p(*specs):
        return P(*specs)

    # divisibility checks are done by the launcher (mesh shape known there);
    # here we encode the *rule*: a dim gets 'model' only if the config's
    # head counts allow it for every supported mesh (16-way TP).
    heads_ok = cfg.n_heads % 16 == 0 and (
        cfg.attn == "mla" or cfg.n_kv_heads % 16 == 0
    )
    atp = tp if heads_ok else None

    layers: dict[str, Any] = {
        "attn_norm": p(None, None),
        "ffn_norm": p(None, None),
    }
    if cfg.attn == "mla":
        if cfg.mla_q_lora:
            layers["wq_a"] = p(None, fsdp, None)
            layers["q_norm"] = p(None, None)
            layers["wq_b"] = p(None, None, atp)
        else:
            layers["wq"] = p(None, fsdp, atp)
        layers["wkv_a"] = p(None, fsdp, None)
        layers["kv_norm"] = p(None, None)
        layers["wkv_b"] = p(None, None, atp)
        layers["wo"] = p(None, atp, fsdp)
    else:
        layers["wq"] = p(None, fsdp, atp)
        layers["wk"] = p(None, fsdp, atp)
        layers["wv"] = p(None, fsdp, atp)
        layers["wo"] = p(None, atp, fsdp)

    if cfg.moe is not None:
        layers["router"] = p(None, fsdp, None)
        layers["we_gate"] = p(None, tp, fsdp, None)
        layers["we_up"] = p(None, tp, fsdp, None)
        layers["we_down"] = p(None, tp, None, fsdp)
        if cfg.moe.n_shared:
            layers["ws_gate"] = p(None, fsdp, tp)
            layers["ws_up"] = p(None, fsdp, tp)
            layers["ws_down"] = p(None, tp, fsdp)
    else:
        layers["w_gate"] = p(None, fsdp, tp)
        layers["w_up"] = p(None, fsdp, tp)
        layers["w_down"] = p(None, tp, fsdp)

    return {
        "embed": p(tp, None),
        "layers": layers,
        "final_norm": p(None),
        "lm_head": p(fsdp, tp),
    }


def lm_cache_shape(cfg: LMConfig, batch: int, smax: int) -> tuple[tuple[int, ...], Any]:
    dt = _dtype(cfg)
    if cfg.attn == "mla":
        return (cfg.n_layers, batch, smax, cfg.mla_kv_lora + cfg.qk_rope_dim), dt
    # gqa: k and v stacked on a leading axis of size 2
    return (2, cfg.n_layers, batch, smax, cfg.n_kv_heads, cfg.d_head), dt


def lm_cache_spec(cfg: LMConfig, mesh_axes: tuple[str, ...]) -> P:
    """KV-cache layout.

    GQA with TP-divisible heads: shard KV heads over 'model'.  Otherwise —
    and for MLA's latent cache (no head dim) — shard the *sequence* dim
    over 'model' (flash-decoding-style split-KV: per-shard partial scores,
    softmax stats reduced across shards by GSPMD).  Without this a 32k
    cache replicates 16× and no decode cell fits a 16 GB chip.
    """
    dp = tuple(a for a in ("pod", "data") if a in mesh_axes)
    tp = "model" if "model" in mesh_axes else None
    if cfg.attn == "mla":
        return P(None, dp, tp, None)  # (L, B, S, lora+rope): S over model
    kv_ok = cfg.n_kv_heads % 16 == 0
    if kv_ok:
        return P(None, None, dp, None, tp, None)
    return P(None, None, dp, tp, None, None)  # (2, L, B, S, KV, dh): S over model


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------


def _layer_fn(cfg: LMConfig, freqs, dp_size: int, collect_cache: bool):
    def layer(carry, lp):
        h, aux = carry
        # barrier: keeps XLA from hoisting the rms_norm f32 cast above the
        # remat save point (which would store the layer-input stack in f32
        # — 2× the residual memory)
        h = jax.lax.optimization_barrier(h)
        x = rms_norm(h, lp["attn_norm"])
        if cfg.attn == "mla":
            attn_out, cache = mla_attention(x, lp, freqs, cfg, chunk=cfg.attn_chunk)
        else:
            attn_out, cache = gqa_attention(x, lp, freqs, cfg, chunk=cfg.attn_chunk)
        sp = "tp" if cfg.seq_parallel else None
        h = constrain(h + attn_out, cfg, "dp", sp, None)
        x = rms_norm(h, lp["ffn_norm"])
        if cfg.moe is not None:
            ffn_out, l_aux = moe_ffn(x, lp, cfg.moe, dp_size=dp_size, cfg=cfg)
            aux = aux + l_aux
        else:
            hidden = constrain(
                swiglu(x @ lp["w_gate"], x @ lp["w_up"]), cfg, "dp", None, "tp"
            )
            ffn_out = hidden @ lp["w_down"]
        # sequence-parallel residual stream (Megatron-SP): the layer output
        # — and therefore the remat-saved per-layer stack — shards its
        # sequence dim over 'model', cutting residual memory by the TP
        # degree.  Row-wise ops (norms, FFN, MoE dispatch) are unaffected;
        # attention projections reshard to head/batch layouts as needed.
        h = constrain(h + ffn_out, cfg, "dp", sp, None)
        # only stack per-layer caches when prefill asks for them — an unused
        # ys stack survives remat+backward as a giant saved residual
        return (h, aux), (cache if collect_cache else None)

    return layer


def lm_forward(
    params: dict,
    tokens: jax.Array,
    cfg: LMConfig,
    *,
    dp_size: int = 1,
    collect_cache: bool = False,
):
    """tokens (B, S) int32 → (logits (B, S, V) f32, aux, cache-or-None)."""
    b, s = tokens.shape
    sp = "tp" if cfg.seq_parallel else None
    h = constrain(params["embed"][tokens], cfg, "dp", sp, None)  # (B, S, D)
    freqs = rope_freqs(_rope_dim(cfg), s, theta=cfg.rope_theta)
    layer = _layer_fn(cfg, freqs, dp_size, collect_cache)
    if cfg.remat:
        layer = jax.checkpoint(layer)
    (h, aux), caches = jax.lax.scan(layer, (h, jnp.float32(0.0)), params["layers"])
    h = rms_norm(h, params["final_norm"])
    logits = constrain(
        (h @ params["lm_head"]).astype(jnp.float32), cfg, "dp", None, "tp"
    )
    if collect_cache:
        return logits, aux, caches
    return logits, aux, None


def lm_hidden(params, tokens, cfg: LMConfig, *, dp_size: int = 1):
    """Final-norm hidden states (B, S, D) — the loss path uses this with a
    chunked cross entropy so the (B, S, V) f32 logits never materialize."""
    b, s = tokens.shape
    sp = "tp" if cfg.seq_parallel else None
    h = constrain(params["embed"][tokens], cfg, "dp", sp, None)
    freqs = rope_freqs(_rope_dim(cfg), s, theta=cfg.rope_theta)
    layer = _layer_fn(cfg, freqs, dp_size, False)
    if cfg.remat:
        layer = jax.checkpoint(layer)
    (h, aux), _ = jax.lax.scan(layer, (h, jnp.float32(0.0)), params["layers"])
    return rms_norm(h, params["final_norm"]), aux


def lm_loss(params, tokens, labels, cfg: LMConfig, *, dp_size: int = 1):
    """Next-token cross entropy, seq-chunked + remat'd.

    The lm_head matmul, logsumexp and gather run per sequence chunk under
    ``jax.checkpoint`` so the peak live set is (B, chunk, V) instead of
    (B, S, V) f32 — for the 49k-128k vocabs this is the difference between
    fitting a 16 GB chip and not (labels -100 → masked).
    """
    h, aux = lm_hidden(params, tokens, cfg, dp_size=dp_size)
    b, s, d = h.shape
    sc = min(cfg.attn_chunk, s)
    pad = (-s) % sc
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-100)
        s = s + pad
    nc = s // sc
    hc = jnp.moveaxis(h.reshape(b, nc, sc, d), 1, 0)  # (nc, B, sc, D)
    lc = jnp.moveaxis(labels.reshape(b, nc, sc), 1, 0)

    def ce_chunk(args):
        hi, li = args
        logits = (hi @ params["lm_head"]).astype(jnp.float32)
        logits = constrain(logits, cfg, "dp", None, "tp")
        mask = li >= 0
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(li, 0)[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mask
        return nll.sum(), mask.sum()

    sums, cnts = jax.lax.map(jax.checkpoint(ce_chunk), (hc, lc))
    loss = sums.sum() / jnp.maximum(cnts.sum(), 1)
    return loss + aux, {"nll": loss, "aux": aux}


def lm_prefill(params, tokens, cfg: LMConfig, *, dp_size: int = 1):
    """Prefill: logits at the last position + the full KV cache."""
    logits, _, caches = lm_forward(
        params, tokens, cfg, dp_size=dp_size, collect_cache=True
    )
    if cfg.attn == "mla":
        cache = caches  # (L, B, S, lora+rope)
    else:
        k, v = caches  # each (L, B, S, KV, dh)
        cache = jnp.stack([k, v])  # (2, L, B, S, KV, dh)
    return logits[:, -1, :], cache


def lm_decode_step(params, cache, token, pos, cfg: LMConfig):
    """One decode step.  token (B,) int32; pos scalar int32.

    cache: (L,B,S,lora+rope) for MLA or (2,L,B,S,KV,dh) for GQA.
    Returns (logits (B, V) f32, new cache).
    """
    b = token.shape[0]
    h = constrain(params["embed"][token], cfg, "dp", None)  # (B, D)
    # cache layouts: MLA (L, B, Smax, lora+rope); GQA (2, L, B, Smax, KV, dh)
    smax = cache.shape[2] if cfg.attn == "mla" else cache.shape[3]
    # rope table over the full cache length
    freqs_all = rope_freqs(_rope_dim(cfg), smax, theta=cfg.rope_theta)

    # The cache is threaded as the scan CARRY (updated in place at the layer
    # index) rather than as xs→ys: the stacked xs/ys formulation makes XLA
    # hold up to four copies of the multi-GB cache (input stack, loop xs, ys
    # accumulator, output); the carry form + donation aliases to ~one.
    l_idx = jnp.arange(cfg.n_layers, dtype=jnp.int32)

    if cfg.attn == "mla":

        def layer(carry, xs):
            h, cache_all = carry
            lp, li = xs
            cache_l = jax.lax.dynamic_index_in_dim(cache_all, li, 0, keepdims=False)
            x = rms_norm(h, lp["attn_norm"])
            attn_out, new_cache_l = mla_decode(x, lp, cache_l, pos, freqs_all, cfg)
            cache_all = jax.lax.dynamic_update_index_in_dim(
                cache_all, new_cache_l.astype(cache_all.dtype), li, 0
            )
            h = h + attn_out
            x = rms_norm(h, lp["ffn_norm"])
            if cfg.moe is not None:
                ffn_out, _ = moe_ffn(x[:, None, :], lp, cfg.moe, dp_size=1, cfg=cfg)
                ffn_out = ffn_out[:, 0, :]
            else:
                ffn_out = swiglu(x @ lp["w_gate"], x @ lp["w_up"]) @ lp["w_down"]
            return (constrain(h + ffn_out, cfg, "dp", None), cache_all), None

        (h, new_cache), _ = jax.lax.scan(layer, (h, cache), (params["layers"], l_idx))
    else:

        def layer(carry, xs):
            h, cache_all = carry  # (2, L, B, S, KV, dh)
            lp, li = xs
            ck = jax.lax.dynamic_index_in_dim(cache_all[0], li, 0, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(cache_all[1], li, 0, keepdims=False)
            x = rms_norm(h, lp["attn_norm"])
            attn_out, nk, nv = gqa_decode(x, lp, ck, cv, pos, freqs_all, cfg)
            pair = jnp.stack([nk, nv]).astype(cache_all.dtype)  # (2, B, S, KV, dh)
            cache_all = jax.lax.dynamic_update_slice(
                cache_all, pair[:, None], (0, li, 0, 0, 0, 0)
            )
            h = h + attn_out
            x = rms_norm(h, lp["ffn_norm"])
            if cfg.moe is not None:
                ffn_out, _ = moe_ffn(x[:, None, :], lp, cfg.moe, dp_size=1, cfg=cfg)
                ffn_out = ffn_out[:, 0, :]
            else:
                ffn_out = swiglu(x @ lp["w_gate"], x @ lp["w_up"]) @ lp["w_down"]
            return (constrain(h + ffn_out, cfg, "dp", None), cache_all), None

        (h, new_cache), _ = jax.lax.scan(layer, (h, cache), (params["layers"], l_idx))

    h = rms_norm(h, params["final_norm"])
    logits = constrain(
        (h @ params["lm_head"]).astype(jnp.float32), cfg, "dp", "tp"
    )
    return logits, new_cache
