"""Top-k routed mixture-of-experts FFN with per-data-shard dispatch.

Sort-based (dropping) dispatch, DeepSeek-style routing (softmax → top-k →
renormalize), shared experts fused as one dense SwiGLU branch.

Sharding story (DESIGN.md §4): tokens are reshaped to (DP, T_loc, D) where
DP is the size of the batch-parallel mesh axes, so dispatch stays *local to
each data shard* — the (DP, E, C, D) buffer shards DP over (pod, data) and
E over ``model`` (expert parallelism).  With a global dispatch the buffer
would be ~80 TB for the 236B config; per-shard it is ~160 MB/device.
Capacity C = ceil(T_loc·k/E · capacity_factor); overflow tokens drop (their
residual path passes through — standard dropping MoE semantics).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import constrain, swiglu

__all__ = ["moe_ffn", "moe_capacity"]


def moe_capacity(t_loc: int, n_experts: int, top_k: int, factor: float) -> int:
    c = int(t_loc * top_k / n_experts * factor) + 1
    return max(8, -(-c // 8) * 8)  # round up to 8 for tiling


def _group_rank(sorted_keys: jax.Array) -> jax.Array:
    """Rank of each element within its contiguous group (sorted input)."""
    n = sorted_keys.shape[0]
    pos = jnp.arange(n, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_keys[1:] != sorted_keys[:-1]]
    )
    group_start = jax.lax.cummax(jnp.where(is_start, pos, 0))
    return pos - group_start


def _dispatch_one_shard(x, gates, eidx, n_experts: int, capacity: int):
    """x (T, D); gates (T, k); eidx (T, k) int32 → (buf (E, C, D), slot (T, k)).

    slot = flattened buffer position for each (token, choice), -1 if dropped.
    """
    t, d = x.shape
    k = eidx.shape[1]
    e_flat = eidx.reshape(-1)  # (T·k,)
    order = jnp.argsort(e_flat, stable=True)
    ranks = _group_rank(e_flat[order])
    # scatter ranks back to (T·k,) order
    rank_flat = jnp.zeros_like(e_flat).at[order].set(ranks)
    keep = rank_flat < capacity
    slot = jnp.where(keep, e_flat * capacity + rank_flat, -1).reshape(t, k)

    # Scatter one routing slot at a time: no (T·k, D) tensor (nor the u32
    # index broadcast in its backward) ever materializes — only (T, D)
    # views of x.
    buf = jnp.zeros((n_experts * capacity, d), x.dtype)
    for ki in range(k):
        sl_k = slot[:, ki]
        # .add (slots are unique) — the backward of scatter-add is a plain
        # gather; scatter-set's backward materializes (E·C, D) masks
        buf = buf.at[jnp.where(sl_k >= 0, sl_k, n_experts * capacity)].add(
            x, mode="drop"
        )
    return buf.reshape(n_experts, capacity, d), slot


def moe_ffn(x, lp, moe, *, dp_size: int = 1, cfg=None):
    """MoE FFN.  x (B, S, D) → (out (B, S, D), aux_loss scalar).

    ``lp``: router (D, E); we_gate/we_up (E, D, F); we_down (E, F, D);
    optional shared ws_gate/ws_up (D, Fs), ws_down (Fs, D).
    ``dp_size``: number of batch-parallel shards — dispatch is local per
    shard (see module docstring).
    """
    b, s, d = x.shape
    e, kk = moe.n_experts, moe.top_k
    t_total = b * s
    assert t_total % dp_size == 0, (t_total, dp_size)
    t_loc = t_total // dp_size
    cap = moe_capacity(t_loc, e, kk, moe.capacity_factor)

    xf = x.reshape(dp_size, t_loc, d)
    xf = constrain(xf, cfg, "dp", None, None) if cfg is not None else xf
    logits = jnp.einsum("gtd,de->gte", xf.astype(jnp.float32), lp["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (DP, T, E)
    gates, eidx = jax.lax.top_k(probs, kk)  # (DP, T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    eidx = eidx.astype(jnp.int32)

    # Switch-style load-balance aux loss (computed over all shards).
    me = probs.mean(axis=(0, 1))  # (E,) mean router prob
    one_hot_top1 = jax.nn.one_hot(eidx[..., 0], e, dtype=jnp.float32)
    ce = one_hot_top1.mean(axis=(0, 1))  # (E,) top-1 load fraction
    aux = moe.aux_coef * e * jnp.sum(me * ce)

    buf, slot = jax.vmap(
        lambda xs, gs, es: _dispatch_one_shard(xs, gs, es, e, cap)
    )(xf, gates, eidx)  # buf (DP, E, C, D); slot (DP, T, k)
    if cfg is not None:
        buf = constrain(buf, cfg, "dp", "tp", None, None)

    h_gate = jnp.einsum("gecd,edf->gecf", buf, lp["we_gate"])
    h_up = jnp.einsum("gecd,edf->gecf", buf, lp["we_up"])
    h = swiglu(h_gate, h_up)
    out_buf = jnp.einsum("gecf,efd->gecd", h, lp["we_down"])  # (DP, E, C, D)
    if cfg is not None:
        out_buf = constrain(out_buf, cfg, "dp", "tp", None, None)

    flat = out_buf.reshape(dp_size, e * cap, d)
    # combine one routing slot at a time — only (DP, T, D) live tensors
    out = jnp.zeros((dp_size, t_loc, d), flat.dtype)
    for ki in range(kk):
        sl_k = slot[:, :, ki]  # (DP, T)
        g = jax.vmap(lambda f, sl: jnp.take(f, jnp.maximum(sl, 0), axis=0))(flat, sl_k)
        g = jnp.where((sl_k >= 0)[..., None], g, 0.0)
        out = out + g * gates[:, :, ki][..., None].astype(g.dtype)

    if "ws_gate" in lp:
        shared = swiglu(xf @ lp["ws_gate"], xf @ lp["ws_up"]) @ lp["ws_down"]
        out = out + shared

    return out.reshape(b, s, d).astype(x.dtype), aux
