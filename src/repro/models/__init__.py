"""Assigned-architecture model definitions (pure JAX, GSPMD-shardable).

  transformer.py  dense + MoE LMs: GQA/MHA + RoPE + SwiGLU, DeepSeek-style
                  MLA (latent KV, absorbed decode), top-k routed experts
  gnn.py          GAT (segment-op message passing) + neighbor sampler
  recsys.py       EmbeddingBag, FM, DeepFM, xDeepFM (CIN), two-tower
  embedding.py    row-sharded embedding lookup (partitioned gather + psum)

All models are config-driven (repro.configs) and expose:
  init(key)                 → params pytree
  param_specs(mesh_axes)    → matching PartitionSpec pytree
  loss / forward functions  consumed by repro.training step factories
"""
