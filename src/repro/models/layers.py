"""Shared layers: RMSNorm, RoPE, initializers, activation."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rms_norm", "rope_freqs", "apply_rope", "dense_init", "swiglu", "constrain"]


def constrain(x: jax.Array, cfg, *dims: str | None) -> jax.Array:
    """Pin activation sharding: dims entries are 'dp', 'tp', or None.

    No-op when the config carries no activation axes (single-device smoke
    tests).  'tp' silently degrades to replicated when the config has no
    tensor-parallel axis (e.g. head counts that don't divide TP).
    """
    if getattr(cfg, "act_dp", None) is None:
        return x
    from jax.sharding import PartitionSpec as P

    resolved = tuple(
        cfg.act_dp if d == "dp" else (cfg.act_tp if d == "tp" else None)
        for d in dims
    )
    return jax.lax.with_sharding_constraint(x, P(*resolved))


def rms_norm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with f32 statistics but no full-tensor f32 materialization.

    The sum-of-squares accumulates in f32 via dot_general directly from the
    bf16 operand; the normalization multiply stays in the input dtype.  A
    full ``x.astype(f32)`` here would become the remat-saved layer residual
    (XLA hoists the cast above the save) and double residual memory.
    """
    d = x.shape[-1]
    sumsq = jax.lax.dot_general(
        x, x,
        (((x.ndim - 1,), (x.ndim - 1,)), (tuple(range(x.ndim - 1)),) * 2),
        preferred_element_type=jnp.float32,
    )  # (...,) f32
    inv = jax.lax.rsqrt(sumsq / d + eps)
    return (x * inv[..., None].astype(x.dtype)) * scale


def rope_freqs(dim: int, max_seq: int, *, theta: float = 10000.0) -> jax.Array:
    """(max_seq, dim/2) complex rotation angles as (cos, sin) stacked last."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    t = jnp.arange(max_seq, dtype=jnp.float32)
    ang = jnp.outer(t, inv)  # (S, dim/2)
    return jnp.stack([jnp.cos(ang), jnp.sin(ang)], axis=-1)  # (S, dim/2, 2)


def apply_rope(x: jax.Array, freqs: jax.Array) -> jax.Array:
    """Rotate last dim of x (..., S, H, D) with freqs (S, D/2, 2)."""
    orig = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    cos = freqs[..., 0]  # (S, D/2)
    sin = freqs[..., 1]
    # broadcast over batch and head axes: x is (..., S, H, D/2)
    cos = cos[:, None, :]
    sin = sin[:, None, :]
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(orig)


def dense_init(key: jax.Array, shape: tuple[int, ...], *, scale: float | None = None,
               dtype=jnp.float32) -> jax.Array:
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up
