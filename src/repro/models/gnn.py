"""GAT (Veličković et al., arXiv:1710.10903) via segment-op message passing.

JAX has no sparse message-passing primitive beyond BCOO; per the assignment
the SpMM/SDDMM regime is implemented directly over an edge list:

    SDDMM  — per-edge attention logits  e_ij = LeakyReLU(aˢ·hᵢ + aᵈ·hⱼ)
    edge-softmax — segment_max/segment_sum over destination segments
    SpMM   — α_ij-weighted message scatter (jax.ops.segment_sum)

Padded edges (src/dst = -1) route to a dead segment and vanish.  The same
layer serves full-batch graphs, sampled-minibatch union subgraphs, and
vmapped batches of small molecule graphs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init

__all__ = [
    "init_gat_params",
    "gat_forward",
    "gat_forward_batched",
    "gat_loss",
    "neighbor_sample",
    "make_random_graph",
]


def init_gat_params(key, cfg, d_feat: int, n_classes: int) -> dict:
    h, dh = cfg.n_heads, cfg.d_hidden
    ks = jax.random.split(key, 6)
    return {
        "w1": dense_init(ks[0], (d_feat, h * dh)),
        "a1_src": dense_init(ks[1], (h, dh), scale=0.1),
        "a1_dst": dense_init(ks[2], (h, dh), scale=0.1),
        "w2": dense_init(ks[3], (h * dh, n_classes)),
        "a2_src": dense_init(ks[4], (1, n_classes), scale=0.1),
        "a2_dst": dense_init(ks[5], (1, n_classes), scale=0.1),
    }


def _gat_layer(h, src, dst, w, a_src, a_dst, *, n_nodes: int, heads: int,
               compute_dtype=None):
    """One GAT layer.  h (N, Din); src/dst (E,) int32 (-1 = padded edge).

    Returns (N, heads, Dout).  ``compute_dtype=bfloat16`` runs the gather/
    message/scatter pipeline (the HBM-bound part) in bf16 with f32 softmax
    statistics — §Perf hillclimb for the ogb_products cell.
    """
    if compute_dtype is not None:
        h = h.astype(compute_dtype)
        w = w.astype(compute_dtype)
    dout = w.shape[1] // heads
    hw = (h @ w).reshape(n_nodes, heads, dout)
    alpha_s = jnp.sum(hw * a_src[None], axis=-1)  # (N, H)
    alpha_d = jnp.sum(hw * a_dst[None], axis=-1)
    valid = (src >= 0) & (dst >= 0)
    s = jnp.where(valid, src, 0)
    t = jnp.where(valid, dst, n_nodes)  # dead segment for pads
    e = jax.nn.leaky_relu(alpha_s[s] + alpha_d[jnp.where(valid, dst, 0)], 0.2)
    e = jnp.where(valid[:, None], e, -jnp.inf)
    # numerically-stable segment softmax over destinations
    seg_max = jax.ops.segment_max(e, t, num_segments=n_nodes + 1)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    ex = jnp.where(valid[:, None], jnp.exp(e - seg_max[t]), 0.0)
    denom = jax.ops.segment_sum(ex, t, num_segments=n_nodes + 1)
    alpha = ex / jnp.maximum(denom[t], 1e-9)  # (E, H) f32 softmax stats
    msg = alpha[:, :, None].astype(hw.dtype) * hw[s]  # (E, H, Dout)
    out = jax.ops.segment_sum(msg, t, num_segments=n_nodes + 1)[:n_nodes]
    return out.astype(jnp.float32)


def gat_forward(params, feats, src, dst, cfg, *, n_classes: int):
    """Two-layer GAT: ELU(concat heads) → single-head logits (N, C)."""
    n = feats.shape[0]
    cd = jnp.bfloat16 if getattr(cfg, "dtype", "float32") == "bfloat16" else None

    def _shard_nodes(x):
        # reduce-scatter the segment accumulation across the batch axes
        # instead of all-reducing the full (N, H, D) table (§Perf hillclimb:
        # −29% memory term, −32% HBM on ogb_products)
        if getattr(cfg, "act_dp", None):
            from jax.sharding import PartitionSpec as P

            return jax.lax.with_sharding_constraint(
                x, P(cfg.act_dp, *([None] * (x.ndim - 1)))
            )
        return x

    h1 = _shard_nodes(_gat_layer(
        feats, src, dst, params["w1"], params["a1_src"], params["a1_dst"],
        n_nodes=n, heads=cfg.n_heads, compute_dtype=cd,
    ))
    h1 = jax.nn.elu(h1.reshape(n, -1))
    h2 = _shard_nodes(_gat_layer(
        h1, src, dst, params["w2"], params["a2_src"], params["a2_dst"],
        n_nodes=n, heads=1, compute_dtype=cd,
    ))
    return h2[:, 0, :]  # (N, C)


def gat_forward_batched(params, feats, src, dst, cfg, *, n_classes: int):
    """Batched small graphs: feats (G, N, F), src/dst (G, E) → (G, C)
    via mean-pooled node logits (molecule-style graph classification)."""

    def one(f, s, d):
        logits = gat_forward(params, f, s, d, cfg, n_classes=n_classes)
        return logits.mean(axis=0)

    return jax.vmap(one)(feats, src, dst)


def gat_loss(params, feats, src, dst, labels, mask, cfg, *, n_classes: int):
    """Masked node-classification cross entropy."""
    logits = gat_forward(params, feats, src, dst, cfg, n_classes=n_classes)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[:, None], axis=-1)[:, 0]
    nll = (lse - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# host-side graph utilities: random graphs + neighbor sampling
# ---------------------------------------------------------------------------


def make_random_graph(
    n_nodes: int, n_edges: int, d_feat: int, n_classes: int, *, seed: int = 0,
    power_law: bool = True,
):
    """Synthetic graph (CSR + features + labels) with optional power-law
    degree distribution (the regime where sampling matters)."""
    rng = np.random.default_rng(seed)
    if power_law:
        w = 1.0 / (np.arange(1, n_nodes + 1) ** 0.8)
        w /= w.sum()
        dst = rng.choice(n_nodes, size=n_edges, p=w)
    else:
        dst = rng.integers(0, n_nodes, size=n_edges)
    src = rng.integers(0, n_nodes, size=n_edges)
    order = np.argsort(dst, kind="stable")
    src, dst = src[order].astype(np.int32), dst[order].astype(np.int32)
    indptr = np.searchsorted(dst, np.arange(n_nodes + 1)).astype(np.int64)
    feats = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    labels = rng.integers(0, n_classes, size=n_nodes).astype(np.int32)
    return {"src": src, "dst": dst, "indptr": indptr, "feats": feats, "labels": labels}


def neighbor_sample(
    graph: dict, seeds: np.ndarray, fanout: tuple[int, ...], *, seed: int = 0
):
    """Layered neighbor sampling (GraphSAGE-style) over the CSR in-edges.

    Returns a fixed-shape union subgraph: node ids (padded), local src/dst
    edge lists (padded with -1), and the local indices of the seeds.
    Shapes depend only on (len(seeds), fanout) — jit-stable.
    """
    rng = np.random.default_rng(seed)
    indptr, src = graph["indptr"], graph["src"]
    frontier = np.asarray(seeds, np.int64)
    nodes = [frontier]
    edges_s: list[np.ndarray] = []
    edges_d: list[np.ndarray] = []
    max_nodes = len(seeds)
    max_edges = 0
    cum = len(seeds)
    for f in fanout:
        max_edges += cum * f
        cum *= f
        max_nodes += cum
    for f in fanout:
        new_s, new_d, nxt = [], [], []
        for v in frontier:
            lo, hi = indptr[v], indptr[v + 1]
            deg = hi - lo
            if deg == 0:
                continue
            take = min(f, int(deg))
            sel = rng.choice(deg, size=take, replace=False) + lo
            nbrs = src[sel]
            new_s.append(nbrs)
            new_d.append(np.full(take, v, np.int64))
            nxt.append(nbrs)
        if new_s:
            edges_s.append(np.concatenate(new_s))
            edges_d.append(np.concatenate(new_d))
            frontier = np.unique(np.concatenate(nxt))
            nodes.append(frontier)
        else:
            frontier = np.array([], np.int64)
    all_nodes = np.unique(np.concatenate(nodes)) if nodes else np.array([], np.int64)
    remap = {int(g): i for i, g in enumerate(all_nodes)}
    es = np.concatenate(edges_s) if edges_s else np.array([], np.int64)
    ed = np.concatenate(edges_d) if edges_d else np.array([], np.int64)
    src_l = np.array([remap[int(v)] for v in es], np.int32)
    dst_l = np.array([remap[int(v)] for v in ed], np.int32)
    seeds_l = np.array([remap[int(v)] for v in seeds], np.int32)
    # pad to static shapes
    node_pad = np.full(max_nodes, -1, np.int64)
    node_pad[: len(all_nodes)] = all_nodes
    e_pad_s = np.full(max_edges, -1, np.int32)
    e_pad_d = np.full(max_edges, -1, np.int32)
    e_pad_s[: len(src_l)] = src_l
    e_pad_d[: len(dst_l)] = dst_l
    return {
        "nodes": node_pad,
        "n_nodes": len(all_nodes),
        "src": e_pad_s,
        "dst": e_pad_d,
        "seeds": seeds_l,
    }
