"""SOGAIC index-build launcher.

    PYTHONPATH=src python -m repro.launch.build_index \
        --dataset sift1m --n 20000 --gamma 4096 --omega 4 --eps 1.8 \
        --workers 8 --ckpt /tmp/sogaic_ckpt [--fail-prob 0.1]

Builds the index with the checkpointed fault-tolerant pipeline, reports
per-stage timings, virtual cluster makespans, overlap stats and recall.
"""

from __future__ import annotations

import argparse
import json

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="sift1m")
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--gamma", type=int, default=4_096)
    ap.add_argument("--omega", type=int, default=4)
    ap.add_argument("--eps", type=float, default=1.8)
    ap.add_argument("--r", type=int, default=32)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--pq-m", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--fail-prob", type=float, default=0.0)
    ap.add_argument("--straggler-prob", type=float, default=0.0)
    ap.add_argument("--queries", type=int, default=100)
    args = ap.parse_args()

    import jax.numpy as jnp

    from repro.checkpoint import CheckpointManager
    from repro.core.pipeline import SOGAICBuilder, SOGAICConfig
    from repro.core.search import brute_force_topk, recall_at_k
    from repro.data.datasets import generate_dataset
    from repro.distributed.cluster_sim import SimulatedCluster

    x, q = generate_dataset(args.dataset, n_override=args.n, n_query=args.queries)
    cfg = SOGAICConfig(
        gamma=args.gamma, omega=args.omega, eps=args.eps, r=args.r,
        n_workers=args.workers, pq_m=args.pq_m,
        sample_size=min(65536, args.n), chunk_size=min(8192, args.n),
    )
    ckpt = CheckpointManager(args.ckpt) if args.ckpt else None
    wrapper = None
    if args.fail_prob or args.straggler_prob:
        cluster = SimulatedCluster(
            args.workers, fail_prob=args.fail_prob,
            straggler_prob=args.straggler_prob, max_failures=5, seed=0,
        )
        wrapper = cluster.wrap
    index, rep = SOGAICBuilder(cfg).build(
        x, ckpt=ckpt, runner_wrapper=wrapper, progress=True
    )
    _, gt = brute_force_topk(jnp.asarray(x), jnp.asarray(q), 10)
    ids, _ = index.search(q, 10, beam_l=64)
    recall = recall_at_k(ids, np.asarray(gt))
    print(json.dumps({
        "n": rep.n, "phi": rep.phi, "avg_overlap": round(rep.avg_overlap, 3),
        "fallbacks": rep.fallback_count,
        "timings_s": {k: round(v, 2) for k, v in rep.timings.items()},
        "build_makespan": round(rep.build_makespan, 2),
        "merge_makespan": round(rep.merge_makespan, 2),
        "graph": rep.graph, "recall_at_10": round(recall, 4),
    }, indent=1))


if __name__ == "__main__":
    main()
