"""Serving launcher — batched ANN queries over a built SOGAIC index.

    PYTHONPATH=src python -m repro.launch.serve --ckpt /tmp/sogaic_ckpt \
        --batches 10 --batch-size 64 --beam 64

Loads the index from a build checkpoint and runs batched beam-search
request waves, reporting latency percentiles and recall (when ground
truth is computable at the loaded scale).
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", required=True)
    ap.add_argument("--batches", type=int, default=10)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--beam", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    import jax.numpy as jnp

    from repro.checkpoint import CheckpointManager
    from repro.core.pipeline import SOGAICIndex
    from repro.core.search import brute_force_topk, recall_at_k

    index = SOGAICIndex.load(CheckpointManager(args.ckpt))
    n, d = index.x.shape
    rng = np.random.default_rng(1)
    lat = []
    recalls = []
    for b in range(args.batches):
        q = index.x[rng.choice(n, args.batch_size)] + rng.normal(
            0, 0.05, (args.batch_size, d)
        ).astype(np.float32)
        t0 = time.perf_counter()
        ids, dists = index.search(q, args.k, beam_l=args.beam)
        lat.append((time.perf_counter() - t0) * 1e3)
        if n <= 100_000:
            _, gt = brute_force_topk(jnp.asarray(index.x), jnp.asarray(q), args.k)
            recalls.append(recall_at_k(ids, np.asarray(gt)))
    lat = np.array(lat[1:])  # drop compile
    print(
        f"batches={args.batches} bs={args.batch_size} "
        f"p50={np.percentile(lat, 50):.1f}ms p99={np.percentile(lat, 99):.1f}ms "
        f"qps={args.batch_size / (lat.mean() / 1e3):.0f}"
        + (f" recall@{args.k}={np.mean(recalls):.4f}" if recalls else "")
    )


if __name__ == "__main__":
    main()
