"""Launchers: production mesh, multi-pod dry-run, train/serve/build drivers."""
