"""Dry-run cells: one (architecture × input-shape) unit of lowering.

A *cell* packages, for a given mesh:
  * the jitted step function (train_step / serve_step / pipeline stage)
  * sharded ``ShapeDtypeStruct`` stand-ins for every input (no allocation)
  * optional output shardings (params/opt/caches keep their layouts)

``build_cell(arch_id, shape_name, mesh)`` → (fn, args, out_shardings).
The dry-run lowers ``fn`` against ``args``, compiles, and extracts the
memory/cost/collective numbers for EXPERIMENTS.md.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import (
    GNNConfig,
    GNNShape,
    LMConfig,
    LMShape,
    RecsysConfig,
    RecsysShape,
    SogaicCellConfig,
)
from repro.models import embedding as emb_mod
from repro.models.gnn import init_gat_params
from repro.models.recsys import (
    init_recsys_params,
    recsys_logits,
    retrieval_scores,
    two_tower_embed,
)
from repro.models.transformer import (
    init_lm_params,
    lm_cache_shape,
    lm_cache_spec,
    lm_decode_step,
    lm_param_specs,
    lm_prefill,
)
from repro.training.optimizer import init_adamw
from repro.training.train_loop import (
    make_gnn_batched_train_step,
    make_gnn_train_step,
    make_lm_train_step,
    make_recsys_train_step,
)

__all__ = ["list_cells", "build_cell", "CellInfo"]


@dataclasses.dataclass(frozen=True)
class CellInfo:
    arch_id: str
    shape_name: str
    kind: str
    skip_reason: str | None = None


def _dp(mesh_axes) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh_axes)


def _dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in _dp(mesh.axis_names)]))


def _ns(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def _sds(mesh: Mesh, shape, dtype, spec: P) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype, sharding=_ns(mesh, spec))


def _shaped_tree(mesh: Mesh, shapes_tree, specs_tree):
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=_ns(mesh, sp)),
        shapes_tree,
        specs_tree,
    )


def _ns_tree(mesh: Mesh, specs_tree):
    return jax.tree.map(lambda sp: _ns(mesh, sp), specs_tree)


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def _lm_activate(cfg: LMConfig, mesh: Mesh) -> LMConfig:
    """Attach activation-sharding axes for this mesh (see LMConfig)."""
    return dataclasses.replace(
        cfg,
        act_dp=_dp(mesh.axis_names),
        act_tp="model" if "model" in mesh.axis_names else None,
    )


def _lm_param_struct(cfg: LMConfig, mesh: Mesh):
    specs = lm_param_specs(cfg, mesh.axis_names)
    shapes = jax.eval_shape(
        functools.partial(init_lm_params, cfg=cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32),
    )
    return _shaped_tree(mesh, shapes, specs), specs


def _lm_opt_struct(cfg: LMConfig, mesh: Mesh, params_sds, specs):
    opt_shapes = jax.eval_shape(
        functools.partial(init_adamw, moment_dtype=cfg.moment_dtype), params_sds
    )
    opt_specs = type(opt_shapes)(m=specs, v=specs, step=P())
    return _shaped_tree(mesh, opt_shapes, opt_specs), opt_specs


def _lm_train_cell(cfg: LMConfig, shape: LMShape, mesh: Mesh):
    cfg = _lm_activate(cfg, mesh)
    dp = _dp(mesh.axis_names)
    params_sds, specs = _lm_param_struct(cfg, mesh)
    opt_sds, opt_specs = _lm_opt_struct(cfg, mesh, params_sds, specs)
    b, s = shape.global_batch, shape.seq_len
    batch_sds = {
        "tokens": _sds(mesh, (b, s), jnp.int32, P(dp, None)),
        "labels": _sds(mesh, (b, s), jnp.int32, P(dp, None)),
    }
    step = make_lm_train_step(cfg, dp_size=_dp_size(mesh), param_specs=specs)
    metric_shapes = jax.eval_shape(step, params_sds, opt_sds, batch_sds)[2]
    out_shardings = (
        _ns_tree(mesh, specs),
        _ns_tree(mesh, opt_specs),
        jax.tree.map(lambda _: _ns(mesh, P()), metric_shapes),
    )
    fn = jax.jit(step, out_shardings=out_shardings, donate_argnums=(0, 1))
    return fn, (params_sds, opt_sds, batch_sds)


def _lm_prefill_cell(cfg: LMConfig, shape: LMShape, mesh: Mesh):
    cfg = _lm_activate(cfg, mesh)
    dp = _dp(mesh.axis_names)
    params_sds, specs = _lm_param_struct(cfg, mesh)
    b, s = shape.global_batch, shape.seq_len
    tokens = _sds(mesh, (b, s), jnp.int32, P(dp, None))
    cache_spec = lm_cache_spec(cfg, mesh.axis_names)
    vocab_tp = "model" if "model" in mesh.axis_names else None

    def step(params, tokens):
        return lm_prefill(params, tokens, cfg, dp_size=_dp_size(mesh))

    fn = jax.jit(
        step,
        out_shardings=(_ns(mesh, P(dp, vocab_tp)), _ns(mesh, cache_spec)),
    )
    return fn, (params_sds, tokens)


def _lm_decode_cell(cfg: LMConfig, shape: LMShape, mesh: Mesh):
    cfg = _lm_activate(cfg, mesh)
    dp = _dp(mesh.axis_names)
    params_sds, specs = _lm_param_struct(cfg, mesh)
    b, s = shape.global_batch, shape.seq_len
    cache_shape, cache_dt = lm_cache_shape(cfg, b, s)
    cache_spec = lm_cache_spec(cfg, mesh.axis_names)
    cache_sds = _sds(mesh, cache_shape, cache_dt, cache_spec)
    token_sds = _sds(mesh, (b,), jnp.int32, P(dp))
    pos_sds = _sds(mesh, (), jnp.int32, P())
    vocab_tp = "model" if "model" in mesh.axis_names else None

    def step(params, cache, token, pos):
        return lm_decode_step(params, cache, token, pos, cfg)

    fn = jax.jit(
        step,
        out_shardings=(_ns(mesh, P(dp, vocab_tp)), _ns(mesh, cache_spec)),
        donate_argnums=(1,),
    )
    return fn, (params_sds, cache_sds, token_sds, pos_sds)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------


def _gnn_param_struct(cfg: GNNConfig, mesh: Mesh, d_feat: int, n_classes: int):
    shapes = jax.eval_shape(
        functools.partial(init_gat_params, cfg=cfg, d_feat=d_feat, n_classes=n_classes),
        jax.ShapeDtypeStruct((2,), jnp.uint32),
    )
    specs = jax.tree.map(lambda _: P(), shapes)  # GAT params are tiny → replicated
    return _shaped_tree(mesh, shapes, specs), specs


def _gnn_full_cell(cfg: GNNConfig, shape: GNNShape, mesh: Mesh):
    dp = _dp(mesh.axis_names)
    cfg = dataclasses.replace(cfg, act_dp=dp)
    n_dev = int(np.prod(list(mesh.shape.values())))
    params_sds, specs = _gnn_param_struct(cfg, mesh, shape.d_feat, shape.n_classes)
    opt_shapes = jax.eval_shape(init_adamw, params_sds)
    opt_specs = type(opt_shapes)(m=specs, v=specs, step=P())
    opt_sds = _shaped_tree(mesh, opt_shapes, opt_specs)
    e_pad = _round_up(shape.n_edges, 512 * max(1, _dp_size(mesh)))
    batch_sds = {
        "feats": _sds(mesh, (shape.n_nodes, shape.d_feat), jnp.float32, P(None, None)),
        "src": _sds(mesh, (e_pad,), jnp.int32, P(dp)),
        "dst": _sds(mesh, (e_pad,), jnp.int32, P(dp)),
        "labels": _sds(mesh, (shape.n_nodes,), jnp.int32, P(None)),
        "mask": _sds(mesh, (shape.n_nodes,), jnp.float32, P(None)),
    }
    step = make_gnn_train_step(cfg, n_classes=shape.n_classes)
    metric_shapes = jax.eval_shape(step, params_sds, opt_sds, batch_sds)[2]
    out_shardings = (
        _ns_tree(mesh, specs),
        _ns_tree(mesh, opt_specs),
        jax.tree.map(lambda _: _ns(mesh, P()), metric_shapes),
    )
    fn = jax.jit(step, out_shardings=out_shardings, donate_argnums=(0, 1))
    return fn, (params_sds, opt_sds, batch_sds)


def _gnn_minibatch_cell(cfg: GNNConfig, shape: GNNShape, mesh: Mesh):
    dp = _dp(mesh.axis_names)
    cfg = dataclasses.replace(cfg, act_dp=dp)
    b = shape.batch_nodes
    f1, f2 = shape.fanout
    max_nodes = b * (1 + f1 + f1 * f2)
    max_edges = _round_up(b * f1 + b * f1 * f2, 512 * max(1, _dp_size(mesh)))
    params_sds, specs = _gnn_param_struct(cfg, mesh, shape.d_feat, shape.n_classes)
    opt_shapes = jax.eval_shape(init_adamw, params_sds)
    opt_specs = type(opt_shapes)(m=specs, v=specs, step=P())
    opt_sds = _shaped_tree(mesh, opt_shapes, opt_specs)
    batch_sds = {
        "feats": _sds(mesh, (max_nodes, shape.d_feat), jnp.float32, P(None, None)),
        "src": _sds(mesh, (max_edges,), jnp.int32, P(dp)),
        "dst": _sds(mesh, (max_edges,), jnp.int32, P(dp)),
        "labels": _sds(mesh, (max_nodes,), jnp.int32, P(None)),
        "mask": _sds(mesh, (max_nodes,), jnp.float32, P(None)),
    }
    step = make_gnn_train_step(cfg, n_classes=shape.n_classes)
    metric_shapes = jax.eval_shape(step, params_sds, opt_sds, batch_sds)[2]
    out_shardings = (
        _ns_tree(mesh, specs),
        _ns_tree(mesh, opt_specs),
        jax.tree.map(lambda _: _ns(mesh, P()), metric_shapes),
    )
    fn = jax.jit(step, out_shardings=out_shardings, donate_argnums=(0, 1))
    return fn, (params_sds, opt_sds, batch_sds)


def _gnn_molecule_cell(cfg: GNNConfig, shape: GNNShape, mesh: Mesh):
    dp = _dp(mesh.axis_names)
    g = _round_up(shape.n_graphs, max(1, _dp_size(mesh)))
    params_sds, specs = _gnn_param_struct(cfg, mesh, shape.d_feat, shape.n_classes)
    opt_shapes = jax.eval_shape(init_adamw, params_sds)
    opt_specs = type(opt_shapes)(m=specs, v=specs, step=P())
    opt_sds = _shaped_tree(mesh, opt_shapes, opt_specs)
    batch_sds = {
        "feats": _sds(mesh, (g, shape.n_nodes, shape.d_feat), jnp.float32, P(dp, None, None)),
        "src": _sds(mesh, (g, shape.n_edges), jnp.int32, P(dp, None)),
        "dst": _sds(mesh, (g, shape.n_edges), jnp.int32, P(dp, None)),
        "labels": _sds(mesh, (g,), jnp.int32, P(dp)),
    }
    step = make_gnn_batched_train_step(cfg, n_classes=shape.n_classes)
    metric_shapes = jax.eval_shape(step, params_sds, opt_sds, batch_sds)[2]
    out_shardings = (
        _ns_tree(mesh, specs),
        _ns_tree(mesh, opt_specs),
        jax.tree.map(lambda _: _ns(mesh, P()), metric_shapes),
    )
    fn = jax.jit(step, out_shardings=out_shardings, donate_argnums=(0, 1))
    return fn, (params_sds, opt_sds, batch_sds)


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------


def _recsys_param_struct(cfg: RecsysConfig, mesh: Mesh):
    """Param SDS with the stacked tables padded to divide the model axis."""
    tp = mesh.shape.get("model", 1)
    pad_to = 128 * tp

    shapes = jax.eval_shape(
        functools.partial(init_recsys_params, cfg=cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32),
    )

    def pad_rows(s):
        return jax.ShapeDtypeStruct((_round_up(s.shape[0], pad_to),) + s.shape[1:], s.dtype)

    shapes = dict(shapes)
    for key in ("table", "linear", "item_table"):
        if key in shapes:
            shapes[key] = pad_rows(shapes[key])

    specs = {k: jax.tree.map(lambda _: P(), v) for k, v in shapes.items()}
    for key in ("table", "linear", "item_table"):
        if key in shapes:
            specs[key] = P("model", None) if "model" in mesh.axis_names else P(None, None)
    return _shaped_tree(mesh, shapes, specs), specs


def _recsys_lookup(mesh: Mesh):
    if "model" in mesh.axis_names and mesh.shape["model"] > 1:
        return emb_mod.make_sharded_lookup(mesh)
    return None


def _recsys_train_cell(cfg: RecsysConfig, shape: RecsysShape, mesh: Mesh):
    dp = _dp(mesh.axis_names)
    params_sds, specs = _recsys_param_struct(cfg, mesh)
    opt_shapes = jax.eval_shape(init_adamw, params_sds)
    opt_specs = type(opt_shapes)(m=specs, v=specs, step=P())
    opt_sds = _shaped_tree(mesh, opt_shapes, opt_specs)
    b = shape.batch
    batch_sds = {
        "sparse": _sds(mesh, (b, cfg.n_sparse), jnp.int32, P(dp, None)),
        "dense": _sds(mesh, (b, cfg.n_dense), jnp.float32, P(dp, None)),
    }
    if cfg.model == "two_tower":
        batch_sds["item_ids"] = _sds(mesh, (b,), jnp.int32, P(dp))
    else:
        batch_sds["labels"] = _sds(mesh, (b,), jnp.int32, P(dp))
    step = make_recsys_train_step(cfg, lookup=_recsys_lookup(mesh))
    metric_shapes = jax.eval_shape(step, params_sds, opt_sds, batch_sds)[2]
    out_shardings = (
        _ns_tree(mesh, specs),
        _ns_tree(mesh, opt_specs),
        jax.tree.map(lambda _: _ns(mesh, P()), metric_shapes),
    )
    fn = jax.jit(step, out_shardings=out_shardings, donate_argnums=(0, 1))
    return fn, (params_sds, opt_sds, batch_sds)


def _recsys_serve_cell(cfg: RecsysConfig, shape: RecsysShape, mesh: Mesh):
    dp = _dp(mesh.axis_names)
    params_sds, specs = _recsys_param_struct(cfg, mesh)
    b = shape.batch
    sparse = _sds(mesh, (b, cfg.n_sparse), jnp.int32, P(dp, None))
    dense = _sds(mesh, (b, cfg.n_dense), jnp.float32, P(dp, None))
    lookup = _recsys_lookup(mesh)

    if cfg.model == "two_tower":

        def step(params, sparse, dense):
            return two_tower_embed(params, cfg, sparse, dense, lookup=lookup)

        fn = jax.jit(step, out_shardings=_ns(mesh, P(dp, None)))
    else:

        def step(params, sparse, dense):
            return jax.nn.sigmoid(
                recsys_logits(params, cfg, sparse, dense, lookup=lookup)
            )

        fn = jax.jit(step, out_shardings=_ns(mesh, P(dp)))
    return fn, (params_sds, sparse, dense)


def _recsys_retrieval_cell(cfg: RecsysConfig, shape: RecsysShape, mesh: Mesh):
    dp = _dp(mesh.axis_names)
    params_sds, specs = _recsys_param_struct(cfg, mesh)
    n_cand = shape.n_candidates

    if cfg.model == "two_tower":
        # one query embedding vs a (model-sharded) candidate matrix + top-k
        b = max(shape.batch, 1)
        d_out = (cfg.tower_mlp or (cfg.embed_dim,))[-1]
        sparse = _sds(mesh, (b, cfg.n_sparse), jnp.int32, P(None, None))
        dense = _sds(mesh, (b, cfg.n_dense), jnp.float32, P(None, None))
        cand = _sds(
            mesh, (n_cand, d_out), jnp.float32,
            P("model" if "model" in mesh.axis_names else None, None),
        )
        # batch-1 query: replicated plain gather (the shard_map lookup
        # needs a dp-divisible batch)
        def step(params, sparse, dense, cand):
            q = two_tower_embed(params, cfg, sparse, dense, lookup=None)
            return retrieval_scores(q, cand, k=100)

        fn = jax.jit(
            step, out_shardings=(_ns(mesh, P(None, None)), _ns(mesh, P(None, None)))
        )
        return fn, (params_sds, sparse, dense, cand)

    # CTR models: score the single query against 1M candidate rows — the
    # candidate item id varies per row, so this is a batch=n_cand forward
    # (vectorized, never a python loop) + top-k of the logits.  The forward
    # is chunked over rows with lax.map: xDeepFM's CIN materializes a
    # (rows, H_k·F, D) tensor per layer, which at 1M rows is 19 GiB/chip —
    # chunking bounds the live set at (chunk, H_k·F, D) (§Perf).
    sparse = _sds(mesh, (n_cand, cfg.n_sparse), jnp.int32, P(dp, None))
    dense = _sds(mesh, (n_cand, cfg.n_dense), jnp.float32, P(dp, None))
    lookup = _recsys_lookup(mesh)
    # chunk must divide n_cand and be divisible by the dp degree;
    # 40,000 = 2^6·5^4 divides 10^6 and both 16- and 32-way dp
    chunk = 40_000 if (n_cand % 40_000 == 0 and 40_000 % max(1, _dp_size(mesh)) == 0) else n_cand

    def step(params, sparse, dense):
        nc = sparse.shape[0] // chunk

        def one(args):
            # keep each chunk's rows spread over the batch axes — GSPMD
            # loses the dim-1 sharding through the reshape+scan otherwise
            sp = jax.lax.with_sharding_constraint(args[0], P(dp, None))
            de = jax.lax.with_sharding_constraint(args[1], P(dp, None))
            return recsys_logits(params, cfg, sp, de, lookup=lookup)

        sc = sparse.reshape(nc, chunk, cfg.n_sparse)
        dc = dense.reshape(nc, chunk, cfg.n_dense)
        logits = jax.lax.map(one, (sc, dc)).reshape(-1)
        vals, idx = jax.lax.top_k(logits, 100)
        return vals, idx.astype(jnp.int32)

    fn = jax.jit(step, out_shardings=(_ns(mesh, P(None)), _ns(mesh, P(None))))
    return fn, (params_sds, sparse, dense)


# ---------------------------------------------------------------------------
# SOGAIC cells (the paper's own pipeline stages)
# ---------------------------------------------------------------------------


def _sogaic_cell(cfg: SogaicCellConfig, shape_name: str, mesh: Mesh):
    from repro.distributed import steps as dsteps

    dp = _dp(mesh.axis_names)
    fa = tuple(mesh.axis_names)
    n_dev = int(np.prod(list(mesh.shape.values())))
    d = cfg.dim

    if shape_name == "assign":
        fn, _ = dsteps.make_assign_step(
            mesh, omega=cfg.omega, gamma=cfg.gamma, eps=cfg.eps, k_cand=cfg.k_cand
        )
        args = (
            _sds(mesh, (cfg.chunk_b, d), jnp.float32, P(dp, None)),
            _sds(mesh, (cfg.phi, d), jnp.float32, P("model", None)),
            _sds(mesh, (cfg.phi,), jnp.int32, P()),
        )
        return fn, args
    if shape_name == "knn":
        fn, _ = dsteps.make_knn_step(mesh, k=cfg.knn_k)
        args = (
            _sds(mesh, (cfg.chunk_b // 4, d), jnp.float32, P(dp, None)),
            _sds(mesh, (cfg.gamma, d), jnp.float32, P("model", None)),
        )
        return fn, args
    if shape_name == "build":
        fn, _ = dsteps.make_build_step(mesh, r=cfg.r, knn_k=cfg.knn_k)
        args = (
            _sds(mesh, (n_dev, cfg.build_subset, d), jnp.float32, P(fa, None, None)),
            _sds(mesh, (n_dev,), jnp.int32, P(fa)),
        )
        return fn, args
    if shape_name == "merge":
        fn, _ = dsteps.make_merge_step(mesh, r=cfg.r)
        t = _round_up(cfg.merge_nodes // 8, n_dev)
        args = (
            _sds(mesh, (cfg.merge_nodes, d), jnp.float32, P(None, None)),
            _sds(mesh, (t,), jnp.int32, P(fa)),
            _sds(mesh, (t, 2 * cfg.r), jnp.int32, P(fa, None)),
        )
        return fn, args
    if shape_name == "pq_encode":
        fn, _ = dsteps.make_pq_encode_step(mesh)
        dsub = d // cfg.pq_m
        args = (
            _sds(mesh, (cfg.chunk_b, d), jnp.float32, P(dp, None)),
            _sds(mesh, (cfg.pq_m, cfg.pq_codes, dsub), jnp.float32, P(None, None, None)),
        )
        return fn, args
    raise KeyError(shape_name)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def list_cells(arch_id: str) -> list[CellInfo]:
    cfg = get_config(arch_id)
    if cfg.family == "lm":
        return [
            CellInfo(arch_id, s.name, s.kind, s.skip_reason) for s in cfg.shapes
        ]
    if cfg.family == "gnn":
        return [CellInfo(arch_id, s.name, s.kind) for s in cfg.shapes]
    if cfg.family == "recsys":
        return [CellInfo(arch_id, s.name, s.kind) for s in cfg.shapes]
    if cfg.family == "sogaic":
        return [CellInfo(arch_id, s, "pipeline") for s in cfg.shapes]
    raise KeyError(cfg.family)


def build_cell(arch_id: str, shape_name: str, mesh: Mesh):
    """Returns (fn, args) for the cell — fn is jitted with shardings."""
    cfg = get_config(arch_id)
    if cfg.family == "lm":
        shape = next(s for s in cfg.shapes if s.name == shape_name)
        if shape.skip_reason:
            raise ValueError(f"cell skipped: {shape.skip_reason}")
        if shape.kind == "train":
            return _lm_train_cell(cfg, shape, mesh)
        if shape.kind == "prefill":
            return _lm_prefill_cell(cfg, shape, mesh)
        return _lm_decode_cell(cfg, shape, mesh)
    if cfg.family == "gnn":
        shape = next(s for s in cfg.shapes if s.name == shape_name)
        if shape.kind == "full_graph":
            return _gnn_full_cell(cfg, shape, mesh)
        if shape.kind == "minibatch":
            return _gnn_minibatch_cell(cfg, shape, mesh)
        return _gnn_molecule_cell(cfg, shape, mesh)
    if cfg.family == "recsys":
        shape = next(s for s in cfg.shapes if s.name == shape_name)
        if shape.kind == "train":
            return _recsys_train_cell(cfg, shape, mesh)
        if shape.kind == "serve":
            return _recsys_serve_cell(cfg, shape, mesh)
        return _recsys_retrieval_cell(cfg, shape, mesh)
    if cfg.family == "sogaic":
        return _sogaic_cell(cfg, shape_name, mesh)
    raise KeyError(cfg.family)
