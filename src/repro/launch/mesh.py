"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialization, and smoke tests must keep seeing 1 device.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: 16×16 = 256 chips (data, model).
    Multi-pod: 2×16×16 = 512 chips (pod, data, model).

    Uses the first prod(shape) devices so the single-pod mesh can be built
    in the same 512-device dry-run process as the multi-pod one.
    """
    import math

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    return jax.make_mesh(
        shape,
        axes,
        devices=jax.devices()[:n],
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def make_test_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")) -> jax.sharding.Mesh:
    """Small mesh for multi-device unit tests (8 host devices)."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )
