"""Training launcher for the assigned architectures.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 50 --batch 8 --seq 256 [--reduced] [--ckpt DIR]

On this CPU container use ``--reduced`` (same-family small config).  On a
real mesh the launcher builds the production mesh and attaches the
sharding specs from repro.launch.cells.
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.training import init_adamw, make_lm_train_step
    from repro.models.transformer import init_lm_params

    cfg = get_config(args.arch)
    assert cfg.family == "lm", "train.py drives LM archs; see build_index/serve"
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(0)
    params = init_lm_params(key, cfg)
    opt = init_adamw(params, moment_dtype=cfg.moment_dtype)
    step = jax.jit(make_lm_train_step(cfg, lr=args.lr))

    ckpt = None
    if args.ckpt:
        from repro.checkpoint import CheckpointManager

        ckpt = CheckpointManager(args.ckpt, async_writes=True)

    rng = np.random.default_rng(0)
    n_tok = args.batch * args.seq
    t0 = time.time()
    for i in range(args.steps):
        toks = jnp.asarray(
            rng.integers(0, cfg.vocab, (args.batch, args.seq)).astype(np.int32)
        )
        batch = {"tokens": toks, "labels": toks}
        params, opt, metrics = step(params, opt, batch)
        if i % 5 == 0 or i == args.steps - 1:
            dt = time.time() - t0
            print(
                f"step {i:4d} loss={float(metrics['loss']):.4f} "
                f"gnorm={float(metrics['gnorm']):.3f} "
                f"tok/s={n_tok*(i+1)/dt:.0f}"
            )
        if ckpt is not None and (i + 1) % args.ckpt_every == 0:
            leaves, _ = jax.tree.flatten(params)
            ckpt.save_arrays(
                f"params_step{i+1}", **{str(j): np.asarray(l) for j, l in enumerate(leaves)}
            )
            ckpt.mark_stage(f"step_{i+1}")
    if ckpt is not None:
        ckpt.close()


if __name__ == "__main__":
    main()
