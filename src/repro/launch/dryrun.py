import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# developer override (still before any jax import): smaller device counts
# make single-cell iteration faster; the deliverable runs use 512.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"]
    )

"""Multi-pod dry-run: lower + compile every (architecture × shape) cell on
the production meshes and record memory / cost / collective analyses.

    PYTHONPATH=src python -m repro.launch.dryrun                 # everything
    PYTHONPATH=src python -m repro.launch.dryrun --arch deepfm   # one arch
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi \
        --arch deepseek-v2-236b --shape train_4k                 # one cell

Results append to ``benchmarks/results/dryrun_<mesh>.jsonl`` (one JSON per
cell) — EXPERIMENTS.md §Dry-run / §Roofline are generated from these.
"""

import argparse
import json
import math
import time
import traceback


def run_cell(arch_id: str, shape_name: str, mesh_kind: str) -> dict:
    import jax

    from repro.launch.cells import build_cell
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import roofline_from_compiled

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = math.prod(mesh.shape.values())
    rec: dict = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": mesh_kind,
        "n_chips": n_chips,
        "status": "ok",
    }
    t0 = time.time()
    try:
        with mesh:
            fn, args = build_cell(arch_id, shape_name, mesh)
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            print(compiled.memory_analysis())
            print({k: v for k, v in (compiled.cost_analysis() or {}).items()
                   if k in ("flops", "bytes accessed")})
            rec.update(roofline_from_compiled(compiled, n_chips))
            rec["t_lower_s"] = round(t_lower, 2)
            rec["t_compile_s"] = round(t_compile, 2)
    except Exception as e:  # noqa: BLE001 — record and continue
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main() -> None:
    from repro.configs import list_archs
    from repro.launch.cells import list_cells

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape cell (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="benchmarks/results")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_archs()
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    os.makedirs(args.out, exist_ok=True)

    n_ok = n_err = n_skip = 0
    for mesh_kind in meshes:
        out_path = os.path.join(args.out, f"dryrun_{mesh_kind}.jsonl")
        done = set()
        if os.path.exists(out_path):
            with open(out_path) as f:
                for line in f:
                    try:
                        r = json.loads(line)
                        if r.get("status") == "ok":
                            done.add((r["arch"], r["shape"]))
                    except json.JSONDecodeError:
                        pass
        with open(out_path, "a") as out:
            for arch in archs:
                for cell in list_cells(arch):
                    if args.shape and cell.shape_name != args.shape:
                        continue
                    if cell.skip_reason:
                        rec = {
                            "arch": arch, "shape": cell.shape_name,
                            "mesh": mesh_kind, "status": "skipped",
                            "skip_reason": cell.skip_reason,
                        }
                        out.write(json.dumps(rec) + "\n")
                        out.flush()
                        n_skip += 1
                        print(f"[{mesh_kind}] {arch}/{cell.shape_name}: SKIP")
                        continue
                    if (arch, cell.shape_name) in done and not args.shape:
                        print(f"[{mesh_kind}] {arch}/{cell.shape_name}: cached")
                        continue
                    print(f"[{mesh_kind}] {arch}/{cell.shape_name}: lowering...")
                    rec = run_cell(arch, cell.shape_name, mesh_kind)
                    out.write(json.dumps(rec) + "\n")
                    out.flush()
                    if rec["status"] == "ok":
                        n_ok += 1
                        print(
                            f"[{mesh_kind}] {arch}/{cell.shape_name}: OK "
                            f"bottleneck={rec['bottleneck']} "
                            f"hbm={rec['peak_hbm_bytes']/2**30:.2f}GiB "
                            f"fits={rec['fits_hbm']} "
                            f"(lower {rec['t_lower_s']}s compile {rec['t_compile_s']}s)"
                        )
                    else:
                        n_err += 1
                        print(f"[{mesh_kind}] {arch}/{cell.shape_name}: ERROR {rec['error']}")
    print(f"\ndry-run complete: {n_ok} ok, {n_err} errors, {n_skip} skipped")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
