"""Roofline term extraction from a compiled dry-run artifact.

Three terms per (arch × shape × mesh), for TPU v5e constants:

  compute    = HLO_FLOPs / peak_FLOPs            (197 TF/s bf16 per chip)
  memory     = HLO_bytes / HBM_bw                (819 GB/s per chip)
  collective = collective_bytes / link_bw        (~50 GB/s per ICI link)

``cost_analysis()`` reports per-device FLOPs/bytes on the post-SPMD
module, so terms are per-chip step latencies directly.  Collective bytes
are not in cost_analysis — we parse the post-partitioning HLO and sum the
result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction (per-device shapes →
per-device wire bytes; the convention is recorded in EXPERIMENTS.md).
"""

from __future__ import annotations

import re
from typing import Any

__all__ = [
    "V5E",
    "parse_collectives",
    "roofline_from_compiled",
    "model_flops_dense",
]

V5E = {
    "peak_flops": 197e12,  # bf16 FLOP/s per chip
    "hbm_bw": 819e9,  # bytes/s per chip
    "link_bw": 50e9,  # bytes/s per ICI link (per direction)
    "hbm_bytes": 16 * 1024**3,
}

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b(pred|[suf]\d+|bf16|c64|c128)\[([\d,]*)\]")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def parse_collectives(hlo_text: str) -> dict[str, dict[str, float]]:
    """Sum result-shape bytes + instruction count per collective kind."""
    out: dict[str, dict[str, float]] = {
        k: {"bytes": 0, "count": 0} for k in _COLLECTIVES
    }
    for line in hlo_text.splitlines():
        for kind in _COLLECTIVES:
            # matches "  %name = TYPE all-gather(" and fusion-free forms;
            # "-start" variants counted once (skip the "-done" halves)
            if f" {kind}(" in line or f" {kind}-start(" in line:
                lhs = line.split("=", 1)
                segment = lhs[1].split("(", 1)[0] if len(lhs) == 2 else line
                out[kind]["bytes"] += _shape_bytes(segment)
                out[kind]["count"] += 1
                break
    return out


def roofline_from_compiled(compiled, n_chips: int, hw: dict = V5E) -> dict[str, Any]:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older API returned [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    coll = parse_collectives(compiled.as_text())
    coll_bytes = sum(v["bytes"] for v in coll.values())

    mem = compiled.memory_analysis()
    mem_per_device = {
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
        "code_bytes": int(mem.generated_code_size_in_bytes),
    }
    peak_hbm = (
        mem_per_device["argument_bytes"]
        + mem_per_device["output_bytes"]
        + mem_per_device["temp_bytes"]
        - mem_per_device["alias_bytes"]
    )

    t_compute = flops / hw["peak_flops"]
    t_memory = bytes_accessed / hw["hbm_bw"]
    t_collective = coll_bytes / hw["link_bw"]
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_collective}
    bottleneck = max(terms, key=terms.get)
    return {
        "n_chips": n_chips,
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": coll_bytes,
        "collectives": coll,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "bottleneck": bottleneck,
        "roofline_bound_s": max(terms.values()),
        "roofline_fraction": (
            t_compute / max(max(terms.values()), 1e-30)
        ),  # fraction of the step the MXU is the binding constraint
        "memory_per_device": mem_per_device,
        "peak_hbm_bytes": peak_hbm,
        "fits_hbm": bool(peak_hbm <= hw["hbm_bytes"]),
    }


def model_flops_dense(n_params_active: float, tokens: float) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) per the assignment."""
    return 6.0 * n_params_active * tokens
