"""repro — SOGAIC: Scalable Overload-Aware Graph-Based ANNS Index Construction.

A production-grade JAX framework reproducing and extending

    Shi et al., "Scalable Overload-Aware Graph-Based Index Construction for
    10-Billion-Scale Vector Similarity Search", WWW Companion '25.

Public API surface (stable):

    repro.core       — partitioning (Algorithm 1), k-means, PQ, graph build,
                       agglomerative merge, scheduling, beam search, pipeline
    repro.data       — dataset registry, synthetic generators, LID, loaders
    repro.distributed— mesh-aware sharded steps + cluster simulation
    repro.kernels    — Pallas TPU kernels with jnp oracles
    repro.models     — assigned architecture model definitions
    repro.configs    — per-architecture configs (``get_config(arch_id)``)
    repro.launch     — mesh construction, dry-run, train/serve/build drivers
"""

__version__ = "1.0.0"

__all__ = [
    "core",
    "data",
    "distributed",
    "kernels",
    "models",
    "configs",
    "launch",
    "training",
    "checkpoint",
]
