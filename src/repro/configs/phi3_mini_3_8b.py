"""phi3-mini-3.8b [dense] — arXiv:2404.14219.

32L d_model=3072 32H (MHA, kv=32) d_ff=8192 vocab=32064.  RoPE + SwiGLU.
"""

from repro.configs.base import LMConfig, LM_SHAPES_FULL_ATTN, register

CONFIG = register(
    LMConfig(
        arch_id="phi3-mini-3.8b",
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        d_head=96,
        d_ff=8192,
        vocab=32064,
        attn="gqa",
        dtype="bfloat16",
        microbatches=4,
        shapes=LM_SHAPES_FULL_ATTN,
    )
)
