"""smollm-360m [dense] — hf:HuggingFaceTB/SmolLM family.

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.  15/5 heads don't
divide TP → attention FSDP-only; d_ff/vocab TP-sharded.
"""

from repro.configs.base import LMConfig, LM_SHAPES_FULL_ATTN, register

CONFIG = register(
    LMConfig(
        arch_id="smollm-360m",
        n_layers=32,
        d_model=960,
        n_heads=15,
        n_kv_heads=5,
        d_head=64,
        d_ff=2560,
        vocab=49152,
        attn="gqa",
        dtype="bfloat16",
        microbatches=2,
        shapes=LM_SHAPES_FULL_ATTN,
    )
)
