"""gat-cora [gnn] — arXiv:1710.10903 (GAT).

2 layers, 8 hidden units/head, 8 heads, attention aggregation.  The four
assigned shape cells span full-batch small (Cora), sampled minibatch
(Reddit-scale w/ 15-10 fanout), full-batch large (ogbn-products) and
batched small molecule graphs.
"""

from repro.configs.base import GNNConfig, GNNShape, register

CONFIG = register(
    GNNConfig(
        arch_id="gat-cora",
        n_layers=2,
        d_hidden=8,
        n_heads=8,
        aggregator="attn",
        shapes=(
            GNNShape("full_graph_sm", "full_graph", 2_708, 10_556, 1_433, n_classes=7),
            GNNShape(
                "minibatch_lg", "minibatch", 232_965, 114_615_892, 602,
                n_classes=41, batch_nodes=1_024, fanout=(15, 10),
            ),
            GNNShape("ogb_products", "full_graph", 2_449_029, 61_859_140, 100, n_classes=47),
            GNNShape("molecule", "batched_small", 30, 64, 16, n_classes=2, n_graphs=128),
        ),
    )
)
