"""Config dataclasses + registry for all assigned architectures.

Every architecture file instantiates one of these and registers it.  The
launcher selects with ``--arch <id>``; the dry-run iterates
``cfg.shapes`` (each a named input-shape cell from the assignment).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

__all__ = [
    "MoESpec",
    "LMConfig",
    "LMShape",
    "GNNConfig",
    "GNNShape",
    "RecsysConfig",
    "RecsysShape",
    "SogaicCellConfig",
    "register",
    "get_config",
    "list_archs",
    "ARCH_REGISTRY",
]

ARCH_REGISTRY: dict[str, Any] = {}


def register(cfg: Any) -> Any:
    ARCH_REGISTRY[cfg.arch_id] = cfg
    return cfg


def get_config(arch_id: str) -> Any:
    if arch_id not in ARCH_REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCH_REGISTRY)}")
    return ARCH_REGISTRY[arch_id]


def list_archs() -> list[str]:
    return sorted(ARCH_REGISTRY)


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    aux_coef: float = 0.001


@dataclasses.dataclass(frozen=True)
class LMShape:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int
    skip_reason: str | None = None  # e.g. long_500k on full-attention archs


@dataclasses.dataclass(frozen=True)
class LMConfig:
    arch_id: str
    family: str = dataclasses.field(default="lm", init=False)
    n_layers: int = 12
    d_model: int = 768
    n_heads: int = 12
    n_kv_heads: int = 12
    d_head: int = 64
    d_ff: int = 3072
    vocab: int = 32000
    attn: str = "gqa"  # "gqa" (covers MHA/MQA) | "mla"
    # MLA dims (DeepSeek-V2)
    mla_kv_lora: int = 512
    mla_q_lora: int = 0  # 0 → direct q projection
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0
    moe: MoESpec | None = None
    dtype: str = "bfloat16"
    shapes: tuple[LMShape, ...] = ()
    # training substrate knobs
    remat: bool = True
    moment_dtype: str = "float32"  # optimizer m/v dtype ("bfloat16" for 236B)
    attn_chunk: int = 512  # query-chunked attention block
    # activation-sharding constraints (set by the launcher; None = off).
    # GSPMD does not reliably propagate batch sharding through the layer
    # scan + chunked attention, so the model pins activations explicitly.
    act_dp: tuple = None  # batch-parallel axes, e.g. ("pod", "data")
    act_tp: str = None  # tensor-parallel axis name ("model")
    # Megatron-style sequence-parallel residual stream: shards the per-layer
    # remat residual stack TP-ways but adds per-layer k/v all-gathers.  On
    # archs that fit HBM without it, turning it off trades memory for a
    # large collective-term reduction (see EXPERIMENTS.md §Perf, llama).
    seq_parallel: bool = True
    # gradient-accumulation microbatches: shrinks every activation /
    # remat-residual buffer by this factor at the cost of one extra
    # gradient buffer (sharded like the params)
    microbatches: int = 1
    grad_accum_dtype: str = "float32"  # 'bfloat16' halves the accumulator
    grad_clip: float = 1.0  # 0 disables the global-norm sync (saves a full
    # f32 materialization of every gradient at the clip barrier)

    def reduced(self, **overrides) -> "LMConfig":
        """A small same-family config for CPU smoke tests."""
        moe = self.moe
        if moe is not None:
            moe = dataclasses.replace(
                moe,
                n_experts=min(moe.n_experts, 8),
                top_k=min(moe.top_k, 2),
                d_ff_expert=64,
            )
        base = dataclasses.replace(
            self,
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_head=16,
            d_ff=128,
            vocab=512,
            moe=moe,
            mla_kv_lora=32,
            mla_q_lora=0,
            qk_nope_dim=16,
            qk_rope_dim=8,
            v_head_dim=16,
            dtype="float32",
            attn_chunk=32,
            shapes=(),
            microbatches=1,
            grad_accum_dtype="float32",
            grad_clip=1.0,
            moment_dtype="float32",
        )
        return dataclasses.replace(base, **overrides)


LM_SHAPES_FULL_ATTN = (
    LMShape("train_4k", "train", 4096, 256),
    LMShape("prefill_32k", "prefill", 32768, 32),
    LMShape("decode_32k", "decode", 32768, 128),
    LMShape(
        "long_500k", "decode", 524288, 1,
        skip_reason="pure full-attention arch — 512k decode requires "
        "sub-quadratic attention (see DESIGN.md §5)",
    ),
)


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GNNShape:
    name: str
    kind: str  # "full_graph" | "minibatch" | "batched_small"
    n_nodes: int
    n_edges: int
    d_feat: int
    n_classes: int = 47
    batch_nodes: int = 0  # minibatch only
    fanout: tuple[int, ...] = ()
    n_graphs: int = 0  # batched_small only


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    arch_id: str
    family: str = dataclasses.field(default="gnn", init=False)
    n_layers: int = 2
    d_hidden: int = 8
    n_heads: int = 8
    aggregator: str = "attn"
    shapes: tuple[GNNShape, ...] = ()
    dtype: str = "float32"
    # node-shard layer outputs over these axes (reduce-scatter the segment
    # accumulation instead of all-reducing the full node table): −29% on the
    # memory term for ogb_products (§Perf) — set by the launcher
    act_dp: tuple = None

    def reduced(self, **overrides) -> "GNNConfig":
        return dataclasses.replace(self, d_hidden=4, n_heads=2, shapes=(), **overrides)


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RecsysShape:
    name: str
    kind: str  # "train" | "serve" | "retrieval"
    batch: int
    n_candidates: int = 0


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    arch_id: str
    family: str = dataclasses.field(default="recsys", init=False)
    model: str = "deepfm"  # deepfm | xdeepfm | fm | two_tower
    n_sparse: int = 39
    n_dense: int = 13
    embed_dim: int = 10
    mlp: tuple[int, ...] = (400, 400, 400)
    cin_layers: tuple[int, ...] = ()
    tower_mlp: tuple[int, ...] = ()
    vocab_sizes: tuple[int, ...] = ()  # per sparse field
    n_items: int = 0  # two-tower candidate vocab
    dtype: str = "float32"
    shapes: tuple[RecsysShape, ...] = ()

    @property
    def total_vocab(self) -> int:
        return int(sum(self.vocab_sizes))

    def reduced(self, **overrides) -> "RecsysConfig":
        n_sparse = min(self.n_sparse, 6)
        return dataclasses.replace(
            self,
            n_sparse=n_sparse,
            embed_dim=4,
            mlp=tuple(min(m, 32) for m in self.mlp),
            cin_layers=tuple(min(c, 8) for c in self.cin_layers),
            tower_mlp=tuple(min(m, 32) for m in self.tower_mlp),
            vocab_sizes=tuple([97, 101, 89, 50, 31, 64][:n_sparse]),
            n_items=256 if self.n_items else 0,
            shapes=(),
            **overrides,
        )


def criteo_like_vocabs(n_fields: int, *, total: int = 33_762_577, seed: int = 7) -> tuple[int, ...]:
    """Heterogeneous per-field vocab sizes (power-law, Criteo-like): a few
    huge id spaces plus many small categorical fields, normalized to a
    realistic total row count."""
    import numpy as np

    rng = np.random.default_rng(seed)
    raw = np.sort(rng.pareto(0.65, size=n_fields) + 1.0)[::-1]
    sizes = np.maximum((raw / raw.sum() * total).astype(np.int64), 4)
    return tuple(int(s) for s in sizes)


RECSYS_SHAPES = (
    RecsysShape("train_batch", "train", 65_536),
    RecsysShape("serve_p99", "serve", 512),
    RecsysShape("serve_bulk", "serve", 262_144),
    RecsysShape("retrieval_cand", "retrieval", 1, n_candidates=1_000_000),
)


# ---------------------------------------------------------------------------
# SOGAIC (the paper's own workload) — dry-run cells for the pipeline stages
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SogaicCellConfig:
    arch_id: str
    family: str = dataclasses.field(default="sogaic", init=False)
    dim: int = 512
    phi: int = 4096  # centroids (Φ) — multiple of TP
    gamma: int = 1_048_576  # Γ per subset
    omega: int = 4
    eps: float = 1.8
    k_cand: int = 32
    r: int = 64
    knn_k: int = 96
    pq_m: int = 64
    pq_codes: int = 256
    chunk_b: int = 1_048_576  # vectors per global assign/encode chunk
    build_subset: int = 65_536  # bucketed subset rows per device build cell
    merge_nodes: int = 2_097_152  # overlap rows re-pruned per merge step
    shapes: tuple[str, ...] = ("assign", "knn", "build", "merge", "pq_encode")
