"""moonshot-v1-16b-a3b [moe] — hf:moonshotai/Moonlight-16B-A3B.

48L d_model=2048 16H (kv=16, MHA) d_ff(expert)=1408 vocab=163840,
MoE 64 routed experts top-6 + 2 shared (DeepSeek-V3-style fine-grained).
"""

from repro.configs.base import LMConfig, LM_SHAPES_FULL_ATTN, MoESpec, register

CONFIG = register(
    LMConfig(
        arch_id="moonshot-v1-16b-a3b",
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_head=128,
        d_ff=1408,
        vocab=163840,
        attn="gqa",
        moe=MoESpec(n_experts=64, top_k=6, n_shared=2, d_ff_expert=1408),
        dtype="bfloat16",
        microbatches=4,
        shapes=LM_SHAPES_FULL_ATTN,
    )
)
