"""SOGAIC's own workload cells (the paper's pipeline stages at VDD10B scale).

dim=512 (VDD10B), Φ=4096 centroids, Γ=1M, Ω=4, ε=1.8 (paper-tuned), R=64.
Chunk sizes picked so per-device working sets fit a 16 GB v5e chip at the
(2, 16, 16) production mesh (see EXPERIMENTS.md §Dry-run).
"""

from repro.configs.base import SogaicCellConfig, register

CONFIG = register(
    SogaicCellConfig(
        arch_id="sogaic-vdd10b",
        dim=512,
        phi=4096,
        gamma=1_048_576,
        omega=4,
        eps=1.8,
        k_cand=32,
        r=64,
        knn_k=96,
        pq_m=64,
        chunk_b=1_048_576,
        build_subset=65_536,
        merge_nodes=2_097_152,
    )
)
