"""deepseek-v2-236b [moe] — arXiv:2405.04434.

60L d_model=5120 128H (GQA kv=128 → MLA) d_ff(expert)=1536 vocab=102400,
MoE 160 routed experts top-6 + 2 shared; MLA kv_lora=512 (q_lora=1536 per
the DeepSeek-V2 paper), qk_nope=128 qk_rope=64 v_head=128.
"""

from repro.configs.base import LMConfig, LM_SHAPES_FULL_ATTN, MoESpec, register

CONFIG = register(
    LMConfig(
        arch_id="deepseek-v2-236b",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        d_head=128,
        d_ff=1536,
        vocab=102400,
        attn="mla",
        mla_kv_lora=512,
        mla_q_lora=1536,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        moe=MoESpec(n_experts=160, top_k=6, n_shared=2, d_ff_expert=1536),
        dtype="bfloat16",
        moment_dtype="bfloat16",
        microbatches=8,
        grad_accum_dtype="bfloat16",
        grad_clip=0.0,  # no global-norm barrier at 236B (see LMConfig)  # 236B: fp32 moments don't fit 16G/chip
        shapes=LM_SHAPES_FULL_ATTN,
    )
)
