"""deepfm [recsys] — arXiv:1703.04247.

39 sparse fields, embed_dim 10, deep MLP 400-400-400, FM interaction.
Criteo-like heterogeneous vocab sizes (~33.7M total rows).
"""

from repro.configs.base import RECSYS_SHAPES, RecsysConfig, criteo_like_vocabs, register

CONFIG = register(
    RecsysConfig(
        arch_id="deepfm",
        model="deepfm",
        n_sparse=39,
        n_dense=13,
        embed_dim=10,
        mlp=(400, 400, 400),
        vocab_sizes=criteo_like_vocabs(39),
        shapes=RECSYS_SHAPES,
    )
)
