"""xdeepfm [recsys] — arXiv:1803.05170.

39 sparse fields, embed_dim 10, CIN layers 200-200-200, deep MLP 400-400.
"""

from repro.configs.base import RECSYS_SHAPES, RecsysConfig, criteo_like_vocabs, register

CONFIG = register(
    RecsysConfig(
        arch_id="xdeepfm",
        model="xdeepfm",
        n_sparse=39,
        n_dense=13,
        embed_dim=10,
        mlp=(400, 400),
        cin_layers=(200, 200, 200),
        vocab_sizes=criteo_like_vocabs(39),
        shapes=RECSYS_SHAPES,
    )
)
