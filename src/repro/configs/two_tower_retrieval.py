"""two-tower-retrieval [recsys] — RecSys'19 (YouTube) sampled-softmax.

embed_dim 256, tower MLP 1024-512-256, dot-product interaction, in-batch
sampled softmax.  ``retrieval_cand`` scores one query against 10⁶
candidates (batched dot + top-k, candidates sharded over the model axis).
This is the arch where SOGAIC applies directly: the candidate tower's
embedding table is exactly what the paper's index construction serves.
"""

from repro.configs.base import RECSYS_SHAPES, RecsysConfig, register

CONFIG = register(
    RecsysConfig(
        arch_id="two-tower-retrieval",
        model="two_tower",
        n_sparse=8,  # user-side categorical features
        n_dense=16,
        embed_dim=256,
        tower_mlp=(1024, 512, 256),
        vocab_sizes=(5_000_000, 2_000_000, 500_000, 100_000, 50_000, 10_000, 1_000, 128),
        n_items=5_000_000,
        shapes=RECSYS_SHAPES,
    )
)
