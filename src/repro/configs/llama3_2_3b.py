"""llama3.2-3b [dense] — hf:meta-llama (llama3 family).

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.
24 heads / 8 kv heads do not divide 16-way TP → attention projections are
FSDP-sharded only (DESIGN.md §5); d_ff and vocab take the TP dimension.
"""

from repro.configs.base import LMConfig, LM_SHAPES_FULL_ATTN, register

CONFIG = register(
    LMConfig(
        arch_id="llama3.2-3b",
        n_layers=28,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_head=128,
        d_ff=8192,
        vocab=128256,
        attn="gqa",
        rope_theta=500000.0,
        dtype="bfloat16",
        microbatches=4,
        shapes=LM_SHAPES_FULL_ATTN,
    )
)
