"""fm [recsys] — Rendle, ICDM'10.

Pure factorization machine: pairwise ⟨vᵢ,vⱼ⟩xᵢxⱼ via the O(nk) sum-square
trick; 39 sparse fields, embed_dim 10.
"""

from repro.configs.base import RECSYS_SHAPES, RecsysConfig, criteo_like_vocabs, register

CONFIG = register(
    RecsysConfig(
        arch_id="fm",
        model="fm",
        n_sparse=39,
        n_dense=13,
        embed_dim=10,
        mlp=(),
        vocab_sizes=criteo_like_vocabs(39),
        shapes=RECSYS_SHAPES,
    )
)
