"""Architecture config registry.

``get_config(arch_id)`` returns the architecture's config object; each
config module also defines its shape cells (the assigned input shapes).
"""

from repro.configs.base import (
    ARCH_REGISTRY,
    GNNConfig,
    GNNShape,
    LMConfig,
    LMShape,
    MoESpec,
    RecsysConfig,
    RecsysShape,
    SogaicCellConfig,
    get_config,
    list_archs,
    register,
)

# importing the modules registers the configs
from repro.configs import (  # noqa: F401  (registration side-effects)
    deepseek_v2_236b,
    moonshot_v1_16b_a3b,
    llama3_2_3b,
    smollm_360m,
    phi3_mini_3_8b,
    gat_cora,
    deepfm,
    two_tower_retrieval,
    xdeepfm,
    fm,
    sogaic,
)

__all__ = [
    "ARCH_REGISTRY",
    "get_config",
    "list_archs",
    "register",
    "LMConfig",
    "LMShape",
    "MoESpec",
    "GNNConfig",
    "GNNShape",
    "RecsysConfig",
    "RecsysShape",
    "SogaicCellConfig",
]
