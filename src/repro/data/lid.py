"""Local intrinsic dimensionality — MLE estimator (Amsaleg et al., KDD'15).

Table 1 reports LID per dataset as a hardness proxy.  The MLE (Levina &
Bickel / Amsaleg) estimator for a point with sorted kNN distances
r_1 ≤ … ≤ r_k is

    LID(x) = − ( (1/k) Σ_{i<k} log(r_i / r_k) )^{-1}

We report the mean over a query sample, computed with the exact
brute-force top-k (jitted matmul path — same op the kernels accelerate).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.search import brute_force_topk

__all__ = ["estimate_lid"]


def estimate_lid(
    x: np.ndarray, *, k: int = 20, sample: int = 1024, seed: int = 0
) -> float:
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    q_idx = rng.choice(n, size=min(sample, n), replace=False)
    q = x[q_idx]
    # k+1 because the nearest hit is the point itself (distance 0)
    dists, _ = brute_force_topk(jnp.asarray(x), jnp.asarray(q), k + 1)
    d = np.asarray(dists)[:, 1:]  # drop self
    d = np.maximum(d, 1e-12)
    rk = d[:, -1:]
    ratios = np.log(d[:, :-1] / rk)
    lid = -1.0 / np.mean(ratios, axis=1)
    lid = lid[np.isfinite(lid)]
    return float(np.mean(lid))
