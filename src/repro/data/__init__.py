"""Datasets: synthetic generators, Table-1 mirror registry, LID, loaders."""

from repro.data.synthetic import make_clustered, make_uniform, make_planted_manifold
from repro.data.datasets import DATASETS, DatasetSpec, generate_dataset
from repro.data.lid import estimate_lid
from repro.data.loader import ChunkLoader

__all__ = [
    "make_clustered",
    "make_uniform",
    "make_planted_manifold",
    "DATASETS",
    "DatasetSpec",
    "generate_dataset",
    "estimate_lid",
    "ChunkLoader",
]
