"""Sharded, prefetching chunk loader for the partition/encode stream.

The 10B deployment streams vectors from distributed storage; each host
reads its shard and double-buffers the next chunk's host→device transfer
while the current chunk is being assigned (compute/transfer overlap —
DESIGN.md §4).  This loader reproduces that structure over an in-memory
or memory-mapped array:

  * ``shard(host_id, n_hosts)`` — static range sharding
  * background prefetch thread keeps ``prefetch`` chunks ready
  * final partial chunk is padded + masked (same contract as
    ``assign_chunk``'s ``valid`` argument)
"""

from __future__ import annotations

import queue
import threading

import numpy as np

__all__ = ["ChunkLoader"]


class ChunkLoader:
    def __init__(
        self,
        x: np.ndarray,
        chunk_size: int,
        *,
        host_id: int = 0,
        n_hosts: int = 1,
        prefetch: int = 2,
        start_chunk: int = 0,
    ) -> None:
        n = x.shape[0]
        per = -(-n // n_hosts)
        self.lo = min(host_id * per, n)
        self.hi = min(self.lo + per, n)
        self.x = x
        self.chunk_size = chunk_size
        self.start_chunk = start_chunk
        self.n_chunks = -(-(self.hi - self.lo) // chunk_size) if self.hi > self.lo else 0
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, prefetch))
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._started = False

    def _produce(self) -> None:
        d = self.x.shape[1]
        for ci in range(self.start_chunk, self.n_chunks):
            lo = self.lo + ci * self.chunk_size
            hi = min(lo + self.chunk_size, self.hi)
            chunk = np.asarray(self.x[lo:hi], dtype=np.float32)
            valid = np.ones((self.chunk_size,), bool)
            if hi - lo < self.chunk_size:
                pad = self.chunk_size - (hi - lo)
                chunk = np.concatenate([chunk, np.zeros((pad, d), np.float32)])
                valid[hi - lo :] = False
            self._q.put((ci, lo, hi, chunk, valid))
        self._q.put(None)

    def __iter__(self):
        if not self._started:
            self._thread.start()
            self._started = True
        while True:
            item = self._q.get()
            if item is None:
                return
            yield item
