"""Dataset registry mirroring the paper's Table 1.

Real datasets (SIFT1B, ISD3B, VDD10B) are not shippable; the registry
reproduces their *shape and hardness* — dim, scale class, LID target,
skew — via the synthetic generators, at a configurable scale factor so
CPU benches run the same code path the 10B deployment would.

``generate_dataset(name, n_override=...)`` returns (base, queries).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.synthetic import make_clustered, make_planted_manifold, make_uniform

__all__ = ["DatasetSpec", "DATASETS", "generate_dataset"]


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    dim: int
    n_base: int  # paper-scale base count (Table 1)
    n_query: int
    lid: float  # paper-reported LID (hardness target)
    kind: str  # generator family
    skew: float = 0.0
    intrinsic_dim: int = 12
    n_clusters: int = 64

    def generate(self, n: int, *, seed: int = 0) -> np.ndarray:
        if self.kind == "manifold":
            return make_planted_manifold(
                n, self.dim, intrinsic_dim=self.intrinsic_dim, seed=seed
            )
        if self.kind == "clustered":
            return make_clustered(
                n, self.dim, n_clusters=self.n_clusters, skew=self.skew,
                intrinsic_noise_dim=self.intrinsic_dim, seed=seed,
            )
        return make_uniform(n, self.dim, seed=seed)


# Table 1 of the paper, with generator settings tuned to land near the
# reported LID at bench scale.
DATASETS: dict[str, DatasetSpec] = {
    "sift1m": DatasetSpec(
        name="sift1m", dim=128, n_base=1_000_000, n_query=10_000, lid=9.3,
        kind="manifold", intrinsic_dim=10,
    ),
    "sift1b": DatasetSpec(
        name="sift1b", dim=128, n_base=1_000_000_000, n_query=10_000, lid=12.9,
        kind="manifold", intrinsic_dim=14,
    ),
    "glove": DatasetSpec(
        name="glove", dim=100, n_base=1_183_514, n_query=10_000, lid=20.0,
        kind="manifold", intrinsic_dim=22,
    ),
    "isd3b": DatasetSpec(
        # high-LID + heavy cluster skew: the dataset where DiskANN's
        # partitioner failed with severe imbalance (paper §3.2.1)
        name="isd3b", dim=256, n_base=3_645_232_672, n_query=10_000, lid=29.1,
        kind="clustered", skew=1.4, n_clusters=96, intrinsic_dim=64,
    ),
    "vdd10b": DatasetSpec(
        name="vdd10b", dim=512, n_base=10_483_835_016, n_query=10_000, lid=10.9,
        kind="manifold", intrinsic_dim=11,
    ),
}


def generate_dataset(
    name: str, *, n_override: int | None = None, n_query: int = 256, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    spec = DATASETS[name]
    n = n_override if n_override is not None else spec.n_base
    base = spec.generate(n + n_query, seed=seed)
    return base[:n], base[n : n + n_query]
