"""Synthetic vector datasets with controllable hardness.

The paper evaluates on image/text/video embeddings whose key properties
are (a) scale, (b) dimensionality, (c) local intrinsic dimensionality
(Table 1's LID column — "the hardness of a dataset") and (d) cluster-size
skew (the property that breaks DiskANN's fixed-closest-ℓ partitioning on
ISD3B).  These generators reproduce those axes:

  make_uniform           flat hypercube — high LID, no structure
  make_clustered         gaussian mixture with power-law cluster masses
                         (``skew`` → Zipf exponent) — the overload stressor
  make_planted_manifold  low-dim manifold embedded in high-dim space —
                         low LID at high ambient dim (SIFT/VDD-like)
"""

from __future__ import annotations

import numpy as np

__all__ = ["make_uniform", "make_clustered", "make_planted_manifold"]


def make_uniform(n: int, d: int, *, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.uniform(-1.0, 1.0, size=(n, d)).astype(np.float32)


def make_clustered(
    n: int,
    d: int,
    *,
    n_clusters: int = 64,
    skew: float = 1.2,
    spread: float = 0.15,
    intrinsic_noise_dim: int = 28,
    seed: int = 0,
) -> np.ndarray:
    """Gaussian mixture with Zipf(``skew``) cluster masses.

    ``skew=0`` → balanced clusters; ``skew≳1`` → a few clusters hold most
    of the mass (the ISD3B failure mode for fixed assignment).  Within-
    cluster offsets live on an ``intrinsic_noise_dim``-dimensional local
    subspace (plus a tiny full-rank jitter), so the measured LID tracks
    that knob instead of the ambient dimension — ISD3B's LID 29.1 at
    dim 256 is unreachable with full-rank cluster noise.
    """
    rng = np.random.default_rng(seed)
    weights = (1.0 / np.arange(1, n_clusters + 1) ** skew) if skew > 0 else np.ones(n_clusters)
    weights = weights / weights.sum()
    counts = rng.multinomial(n, weights)
    centers = rng.normal(0.0, 1.0, size=(n_clusters, d))
    k = min(intrinsic_noise_dim, d)
    out = np.empty((n, d), np.float32)
    pos = 0
    for c, cnt in enumerate(counts):
        basis = rng.normal(size=(k, d)) / np.sqrt(k)
        z = rng.normal(0.0, spread, size=(cnt, k))
        jitter = rng.normal(0.0, spread * 0.02, size=(cnt, d))
        out[pos : pos + cnt] = centers[c] + z @ basis + jitter
        pos += cnt
    rng.shuffle(out)
    return out


def make_planted_manifold(
    n: int,
    d: int,
    *,
    intrinsic_dim: int = 12,
    noise: float = 0.01,
    seed: int = 0,
) -> np.ndarray:
    """Random smooth embedding of a ``intrinsic_dim``-dim latent into R^d.

    LID of the result tracks ``intrinsic_dim`` (plus noise floor), letting
    benchmarks reproduce Table 1's LID spread (9.3 … 29.1) at any scale.
    """
    rng = np.random.default_rng(seed)
    z = rng.normal(size=(n, intrinsic_dim))
    w1 = rng.normal(size=(intrinsic_dim, 2 * d)) / np.sqrt(intrinsic_dim)
    w2 = rng.normal(size=(2 * d, d)) / np.sqrt(2 * d)
    x = np.tanh(z @ w1) @ w2
    x += rng.normal(0.0, noise, size=x.shape)
    return x.astype(np.float32)
