"""AdamW with configurable moment dtype and fully sharded states.

Moments inherit each parameter's sharding (same pytree structure → same
PartitionSpecs), which is ZeRO-style optimizer-state sharding for free
under GSPMD: with params FSDP-sharded over the batch axes, m/v shards
follow.  ``moment_dtype='bfloat16'`` halves optimizer HBM for the 236B
config (DESIGN.md §4); update math is always f32.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "init_adamw", "adamw_update"]


class AdamWState(NamedTuple):
    m: dict
    v: dict
    step: jax.Array


def init_adamw(params, *, moment_dtype: str = "float32") -> AdamWState:
    dt = jnp.dtype(moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def adamw_update(
    params,
    grads,
    opt: AdamWState,
    *,
    lr: float = 1e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    grad_clip: float = 1.0,
):
    step = opt.step + 1
    stepf = step.astype(jnp.float32)

    if grad_clip > 0:
        gsq = sum(
            jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)
        )
        gnorm = jnp.sqrt(gsq)
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
    else:
        gnorm = jnp.float32(0.0)
        scale = jnp.float32(1.0)

    bc1 = 1.0 - b1 ** stepf
    bc2 = 1.0 - b2 ** stepf

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p32)
        return p32.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, opt.m, opt.v)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(m=new_m, v=new_v, step=step), gnorm
