"""Train-step factories for the three architecture families.

Each factory returns a pure ``step(params, opt, batch) → (params, opt,
metrics)`` suitable for jit-with-shardings (the launcher attaches
PartitionSpecs) and for single-device smoke tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.gnn import gat_loss
from repro.models.recsys import recsys_loss, two_tower_loss
from repro.models.transformer import lm_loss
from repro.training.optimizer import adamw_update

__all__ = [
    "make_lm_train_step",
    "make_gnn_train_step",
    "make_recsys_train_step",
]


def make_lm_train_step(cfg, *, dp_size: int = 1, lr: float = 1e-4, param_specs=None):
    mb = max(1, getattr(cfg, "microbatches", 1))
    acc_dt = jnp.dtype(getattr(cfg, "grad_accum_dtype", "float32"))

    def loss_fn(p, tokens, labels):
        return lm_loss(p, tokens, labels, cfg, dp_size=dp_size)

    def _c(tree):
        # keep gradients sharded like the params — otherwise XLA replicates
        # multi-GB embed/lm_head gradients on every device
        if param_specs is None:
            return tree
        return jax.tree.map(
            lambda t, sp: jax.lax.with_sharding_constraint(t, sp),
            tree, param_specs,
        )

    def step(params, opt, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        if mb == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, tokens, labels
            )
            grads = _c(grads)
        else:
            b, s = tokens.shape
            assert b % mb == 0, (b, mb)
            # strided microbatch split: row r goes to microbatch r % mb, so
            # every microbatch stays spread across all data shards
            tkn = jnp.moveaxis(tokens.reshape(b // mb, mb, s), 1, 0)
            lbl = jnp.moveaxis(labels.reshape(b // mb, mb, s), 1, 0)

            def acc_fn(carry, mb_batch):
                g_acc, loss_acc, aux_acc = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb_batch[0], mb_batch[1]
                )
                g = _c(g)
                g_acc = _c(jax.tree.map(
                    lambda a, gi: a + gi.astype(acc_dt) / mb, g_acc, g
                ))
                return (g_acc, loss_acc + l / mb, aux_acc + m["aux"] / mb), None

            g0 = _c(jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params))
            (grads, loss, aux), _ = jax.lax.scan(
                acc_fn, (g0, jnp.float32(0.0), jnp.float32(0.0)), (tkn, lbl)
            )
            metrics = {"nll": loss - aux, "aux": aux}
        params, opt, gnorm = adamw_update(
            params, grads, opt, lr=lr,
            grad_clip=getattr(cfg, "grad_clip", 1.0),
        )
        return params, opt, {"loss": loss, "gnorm": gnorm, **metrics}

    return step


def make_gnn_train_step(cfg, *, n_classes: int, lr: float = 5e-3):
    def step(params, opt, batch):
        def loss_fn(p):
            return gat_loss(
                p, batch["feats"], batch["src"], batch["dst"],
                batch["labels"], batch["mask"], cfg, n_classes=n_classes,
            )

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt, gnorm = adamw_update(params, grads, opt, lr=lr, weight_decay=0.0)
        return params, opt, {"loss": loss, "gnorm": gnorm}

    return step


def make_gnn_batched_train_step(cfg, *, n_classes: int, lr: float = 5e-3):
    """Batched small-graph classification (molecule cell)."""
    from repro.models.gnn import gat_forward_batched

    def step(params, opt, batch):
        def loss_fn(p):
            logits = gat_forward_batched(
                p, batch["feats"], batch["src"], batch["dst"], cfg,
                n_classes=n_classes,
            )
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(
                logits, jnp.maximum(batch["labels"], 0)[:, None], axis=-1
            )[:, 0]
            return jnp.mean(lse - gold)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt, gnorm = adamw_update(params, grads, opt, lr=lr, weight_decay=0.0)
        return params, opt, {"loss": loss, "gnorm": gnorm}

    return step


def make_recsys_train_step(cfg, *, lr: float = 1e-3, lookup=None):
    if cfg.model == "two_tower":

        def step(params, opt, batch):
            def loss_fn(p):
                return two_tower_loss(
                    p, cfg, batch["sparse"], batch["dense"], batch["item_ids"],
                    lookup=lookup,
                )

            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
            params, opt, gnorm = adamw_update(params, grads, opt, lr=lr)
            return params, opt, {"loss": loss, "gnorm": gnorm, **metrics}

        return step

    def step(params, opt, batch):
        def loss_fn(p):
            return recsys_loss(
                p, cfg, batch["sparse"], batch["dense"], batch["labels"],
                lookup=lookup,
            )

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt, gnorm = adamw_update(params, grads, opt, lr=lr)
        return params, opt, {"loss": loss, "gnorm": gnorm, **metrics}

    return step
