"""Gradient compression for DP all-reduce (distributed-optimization trick).

Two methods, both with residual error feedback (Seide et al. / 1-bit SGD
lineage) so compression error accumulates into the next step instead of
biasing the trajectory:

  * ``bf16``  — cast-reduce-cast: halves all-reduce bytes, near-lossless
  * ``int8``  — per-tensor max-abs scaling to int8: 4× fewer bytes

Usage is explicit-DP (shard_map over the batch axes): GSPMD's implicit
gradient all-reduce cannot be intercepted, so the compressed trainer is a
shard_map variant (`compressed_psum`) exercised by the multi-device tests
and available via ``make_lm_train_step(..., grad_compression=...)`` for
pure-DP meshes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compress", "decompress", "compressed_psum", "apply_error_feedback"]


def compress(g: jax.Array, method: str):
    if method == "bf16":
        return g.astype(jnp.bfloat16), None
    if method == "int8":
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        return q, scale
    raise ValueError(method)


def decompress(q: jax.Array, scale, method: str):
    if method == "bf16":
        return q.astype(jnp.float32)
    if method == "int8":
        return q.astype(jnp.float32) * scale
    raise ValueError(method)


def compressed_psum(g: jax.Array, axes, method: str = "bf16"):
    """psum with on-the-wire compression (call inside shard_map)."""
    q, scale = compress(g, method)
    if method == "int8":
        # int8 summing overflows; widen to int32 for the reduce, keep the
        # wire format 8-bit conceptually (XLA models the operand bytes)
        total = jax.lax.psum(q.astype(jnp.int32), axes)
        scale = jax.lax.pmax(scale, axes)
        return total.astype(jnp.float32) * scale
    return jax.lax.psum(q, axes).astype(jnp.float32)


def apply_error_feedback(g: jax.Array, residual: jax.Array, method: str):
    """Returns (compressed-then-decompressed grad, new residual)."""
    g_corr = g.astype(jnp.float32) + residual
    q, scale = compress(g_corr, method)
    deq = decompress(q, scale, method)
    return deq, g_corr - deq
