"""Training substrate: optimizer, step factories, gradient compression."""

from repro.training.optimizer import adamw_update, init_adamw
from repro.training.train_loop import (
    make_gnn_train_step,
    make_lm_train_step,
    make_recsys_train_step,
)

__all__ = [
    "init_adamw",
    "adamw_update",
    "make_lm_train_step",
    "make_gnn_train_step",
    "make_recsys_train_step",
]
