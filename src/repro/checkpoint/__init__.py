"""Atomic, manifest-based checkpointing for multi-stage builds and training."""

from repro.checkpoint.manager import CheckpointManager

__all__ = ["CheckpointManager"]
