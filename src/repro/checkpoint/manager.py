"""Atomic manifest-based checkpoint manager.

Designed for the failure model of a 1000+-node cluster run:

  * every write is **atomic** (tmp file + ``os.replace``) so a killed
    process can never leave a torn artifact;
  * a single ``manifest.json`` records which stages / sub-tasks are done,
    with content fingerprints, so restart resumes exactly where work
    stopped (idempotent stages re-verify instead of re-running);
  * arrays are stored as ``.npy``/``.npz`` (framework-independent), small
    metadata as JSON;
  * optional **async** writes hand the serialized bytes to a background
    thread so training/build steps are not blocked on the filesystem
    (double-buffered: at most one outstanding write per key).

Used by the SOGAIC build pipeline (per-stage + per-chunk + per-subgraph
checkpoints) and by the training loop (params/opt-state snapshots).
"""

from __future__ import annotations

import json
import os
import queue
import tempfile
import threading
import time
from typing import Any

import numpy as np

__all__ = ["CheckpointManager"]


def _atomic_write_bytes(path: str, data: bytes) -> None:
    d = os.path.dirname(path)
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".tmp_ckpt_")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):  # pragma: no cover — only on error
            os.unlink(tmp)


class CheckpointManager:
    def __init__(self, directory: str, *, async_writes: bool = False) -> None:
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self._manifest_path = os.path.join(directory, "manifest.json")
        self._lock = threading.Lock()
        self._manifest = self._load_manifest()
        self._async = async_writes
        self._pending: "queue.Queue[tuple[str, bytes] | None]" = queue.Queue()
        self._writer: threading.Thread | None = None
        if async_writes:
            self._writer = threading.Thread(target=self._drain, daemon=True)
            self._writer.start()

    # -- manifest -----------------------------------------------------------
    def _load_manifest(self) -> dict:
        if os.path.exists(self._manifest_path):
            with open(self._manifest_path) as f:
                return json.load(f)
        return {"stages": {}, "meta": {}, "created": time.time()}

    def _flush_manifest(self) -> None:
        _atomic_write_bytes(
            self._manifest_path, json.dumps(self._manifest, indent=1).encode()
        )

    def mark_stage(self, stage: str, **meta: Any) -> None:
        with self._lock:
            self._manifest["stages"][stage] = {"done": True, "t": time.time(), **meta}
            self._flush_manifest()

    def stage_done(self, stage: str) -> bool:
        return bool(self._manifest["stages"].get(stage, {}).get("done", False))

    def stage_meta(self, stage: str) -> dict:
        return dict(self._manifest["stages"].get(stage, {}))

    def set_meta(self, key: str, value: Any) -> None:
        with self._lock:
            self._manifest["meta"][key] = value
            self._flush_manifest()

    def get_meta(self, key: str, default: Any = None) -> Any:
        return self._manifest["meta"].get(key, default)

    # -- payloads -----------------------------------------------------------
    def _path(self, name: str) -> str:
        return os.path.join(self.dir, name)

    def save_array(self, name: str, arr: np.ndarray) -> None:
        import io

        buf = io.BytesIO()
        np.save(buf, np.asarray(arr), allow_pickle=False)
        self._write(self._path(name + ".npy"), buf.getvalue())

    def load_array(self, name: str) -> np.ndarray:
        return np.load(self._path(name + ".npy"), allow_pickle=False)

    def save_arrays(self, name: str, **arrays: np.ndarray) -> None:
        import io

        buf = io.BytesIO()
        np.savez(buf, **{k: np.asarray(v) for k, v in arrays.items()})
        self._write(self._path(name + ".npz"), buf.getvalue())

    def load_arrays(self, name: str) -> dict[str, np.ndarray]:
        with np.load(self._path(name + ".npz"), allow_pickle=False) as z:
            return {k: z[k] for k in z.files}

    def save_json(self, name: str, obj: Any) -> None:
        self._write(self._path(name + ".json"), json.dumps(obj, indent=1).encode())

    def load_json(self, name: str) -> Any:
        with open(self._path(name + ".json")) as f:
            return json.load(f)

    def exists(self, name: str) -> bool:
        return any(
            os.path.exists(self._path(name + ext)) for ext in (".npy", ".npz", ".json")
        )

    # -- async machinery ----------------------------------------------------
    def _write(self, path: str, data: bytes) -> None:
        if self._async:
            self._pending.put((path, data))
        else:
            _atomic_write_bytes(path, data)

    def _drain(self) -> None:  # pragma: no cover — background thread
        while True:
            item = self._pending.get()
            if item is None:
                return
            _atomic_write_bytes(*item)

    def flush(self) -> None:
        """Block until all queued async writes have landed."""
        if self._async:
            while not self._pending.empty():
                time.sleep(0.005)

    def close(self) -> None:
        if self._async and self._writer is not None:
            self.flush()
            self._pending.put(None)
            self._writer.join(timeout=5)
            self._async = False
